//! Capacitor energy-storage model.
//!
//! The paper's platforms buffer harvested energy in a capacitor
//! (0.2 F supercap / 50 mF / 6 mF for the three apps) and the MCU runs
//! between a wake threshold `v_on` and a brown-out threshold `v_off`
//! (§3.4: "the system sleeps and wakes up multiple times during the
//! execution of an action"). Energy accounting is E = ½·C·V².

/// Capacitor with charge/discharge bookkeeping.
#[derive(Debug, Clone)]
pub struct Capacitor {
    /// Capacitance in farads.
    pub c_f: f64,
    /// Maximum (clamp) voltage.
    pub v_max: f64,
    /// Wake-up threshold: the system boots when V reaches this.
    pub v_on: f64,
    /// Brown-out threshold: execution dies below this.
    pub v_off: f64,
    /// Leakage, watts (parasitic + sleep current).
    pub leak_w: f64,
    /// Harvest conversion efficiency in (0, 1].
    pub eff: f64,
    /// Current voltage.
    v: f64,
}

impl Capacitor {
    /// New capacitor starting fully discharged (at `v_off`).
    pub fn new(c_f: f64, v_max: f64, v_on: f64, v_off: f64) -> Self {
        assert!(v_max >= v_on && v_on > v_off && v_off >= 0.0);
        Capacitor {
            c_f,
            v_max,
            v_on,
            v_off,
            leak_w: 2e-6,
            eff: 0.8,
            v: v_off,
        }
    }

    /// The air-quality platform's 0.2 F supercap (§6.1).
    pub fn air_quality() -> Self {
        Capacitor::new(0.2, 3.3, 2.8, 2.0)
    }

    /// The presence platform's 50 mF cap (§6.2).
    pub fn presence() -> Self {
        Capacitor::new(0.050, 3.3, 2.8, 2.0)
    }

    /// The vibration platform's 6 mF cap (§6.3, min operating 2 V).
    pub fn vibration() -> Self {
        Capacitor::new(0.006, 3.3, 2.8, 2.0)
    }

    /// Current voltage.
    pub fn voltage(&self) -> f64 {
        self.v
    }

    /// Stored energy above absolute zero, µJ.
    pub fn energy_uj(&self) -> f64 {
        0.5 * self.c_f * self.v * self.v * 1e6
    }

    /// Usable energy above the brown-out threshold, µJ.
    pub fn usable_uj(&self) -> f64 {
        (0.5 * self.c_f * (self.v * self.v - self.v_off * self.v_off) * 1e6).max(0.0)
    }

    /// Budget of one full charge cycle (v_max -> v_off), µJ. This is the
    /// per-action energy ceiling the pre-inspection tool enforces.
    pub fn full_budget_uj(&self) -> f64 {
        0.5 * self.c_f * (self.v_max * self.v_max - self.v_off * self.v_off) * 1e6
    }

    /// Integrate harvesting for `dt_us` at constant input power `p_w`.
    pub fn charge(&mut self, p_w: f64, dt_us: u64) {
        let dt_s = dt_us as f64 / 1e6;
        let de_j = (p_w * self.eff - self.leak_w) * dt_s;
        let e_j = (0.5 * self.c_f * self.v * self.v + de_j).max(0.0);
        self.v = (2.0 * e_j / self.c_f).sqrt().min(self.v_max);
    }

    /// Try to spend `e_uj` of usable energy. Returns `true` on success;
    /// on failure the capacitor drains to `v_off` (the partial execution
    /// consumed the remaining usable charge — the brown-out case).
    pub fn deduct_uj(&mut self, e_uj: f64) -> bool {
        if e_uj <= self.usable_uj() {
            let e_j = 0.5 * self.c_f * self.v * self.v - e_uj * 1e-6;
            self.v = (2.0 * e_j / self.c_f).sqrt();
            true
        } else {
            self.v = self.v_off;
            false
        }
    }

    /// Is the voltage at/above the wake threshold?
    pub fn awake_ready(&self) -> bool {
        self.v >= self.v_on
    }

    /// Is the voltage above brown-out?
    pub fn alive(&self) -> bool {
        self.v > self.v_off
    }

    /// Force a voltage (testing / scenario setup).
    pub fn set_voltage(&mut self, v: f64) {
        self.v = v.clamp(0.0, self.v_max);
    }

    /// Time to charge from the current voltage to `v_on` at constant power,
    /// seconds; `None` if input power does not exceed leakage.
    pub fn time_to_wake_s(&self, p_w: f64) -> Option<f64> {
        if self.v >= self.v_on {
            return Some(0.0);
        }
        let net = p_w * self.eff - self.leak_w;
        if net <= 0.0 {
            return None;
        }
        let de_j = 0.5 * self.c_f * (self.v_on * self.v_on - self.v * self.v);
        Some(de_j / net)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cap() -> Capacitor {
        let mut c = Capacitor::new(0.006, 3.3, 2.8, 2.0);
        c.leak_w = 0.0;
        c.eff = 1.0;
        c
    }

    #[test]
    fn energy_formula() {
        let mut c = cap();
        c.set_voltage(3.0);
        // 0.5 * 6 mF * 9 V^2 = 27 mJ
        assert!((c.energy_uj() - 27_000.0).abs() < 1.0);
        // usable above 2 V: 0.5 * 6 mF * (9 - 4) = 15 mJ
        assert!((c.usable_uj() - 15_000.0).abs() < 1.0);
    }

    #[test]
    fn charging_raises_voltage_to_clamp() {
        let mut c = cap();
        // 10 mW for 10 s = 100 mJ >> capacity -> clamps at v_max
        c.charge(0.010, 10_000_000);
        assert!((c.voltage() - 3.3).abs() < 1e-9);
    }

    #[test]
    fn deduct_success_and_brownout() {
        let mut c = cap();
        c.set_voltage(3.0);
        assert!(c.deduct_uj(10_000.0)); // 10 mJ of 15 mJ usable
        assert!(c.usable_uj() < 15_000.0);
        assert!(!c.deduct_uj(1e9)); // brown-out
        assert!((c.voltage() - c.v_off).abs() < 1e-12);
        assert!(!c.awake_ready());
    }

    #[test]
    fn time_to_wake_matches_integration() {
        let mut c = cap();
        let p = 0.005; // 5 mW
        let t = c.time_to_wake_s(p).unwrap();
        c.charge(p, (t * 1e6) as u64 + 1);
        assert!(c.awake_ready());
    }

    #[test]
    fn time_to_wake_none_when_too_dark() {
        let mut c = cap();
        c.leak_w = 1e-3;
        assert!(c.time_to_wake_s(0.5e-3).is_none());
    }

    #[test]
    fn leakage_discharges_over_time() {
        let mut c = cap();
        c.leak_w = 1e-4;
        c.set_voltage(2.5);
        let e0 = c.energy_uj();
        c.charge(0.0, 10_000_000);
        assert!(c.energy_uj() < e0);
    }

    #[test]
    fn paper_platform_constructors() {
        assert_eq!(Capacitor::air_quality().c_f, 0.2);
        assert_eq!(Capacitor::presence().c_f, 0.050);
        assert_eq!(Capacitor::vibration().c_f, 0.006);
    }
}
