//! Run-time energy metering — the simulator's EnergyTrace analogue.
//!
//! Accumulates per-action energy/time/counts and a cumulative-energy time
//! series; Figs. 11, 14, 16 and 17 are generated from this record.

use crate::actions::Action;
use std::collections::BTreeMap;

/// One row of the per-action accounting table.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ActionTally {
    pub count: u64,
    pub energy_uj: f64,
    pub time_us: u64,
    /// Number of attempts that died mid-action (power failure, rolled back).
    pub aborted: u64,
    /// Energy wasted in aborted attempts, µJ.
    pub wasted_uj: f64,
}

/// Energy meter: per-action tallies plus framework-overhead tallies.
///
/// Keys are owned strings so a meter can be restored from persisted run
/// state ([`crate::sim::state`]); the hot [`EnergyMeter::record`] path
/// only allocates the first time a key appears.
#[derive(Debug, Clone, Default)]
pub struct EnergyMeter {
    per_action: BTreeMap<String, ActionTally>,
    /// (t_us, cumulative µJ) samples, appended on every completed charge.
    pub series: Vec<(u64, f64)>,
    total_uj: f64,
}

impl EnergyMeter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Rebuild a meter from persisted parts (the run-state restore path).
    pub fn from_parts(
        tallies: Vec<(String, ActionTally)>,
        series: Vec<(u64, f64)>,
        total_uj: f64,
    ) -> EnergyMeter {
        EnergyMeter {
            per_action: tallies.into_iter().collect(),
            series,
            total_uj,
        }
    }

    fn entry(&mut self, key: &str) -> &mut ActionTally {
        // the Entry API would force an owned key per call; checking first
        // keeps the hot path allocation-free (the clone happens only on a
        // key's first appearance)
        #[allow(clippy::map_entry)]
        if !self.per_action.contains_key(key) {
            self.per_action
                .insert(key.to_string(), ActionTally::default());
        }
        self.per_action.get_mut(key).expect("just inserted")
    }

    /// Record a completed action (or overhead component like "planner").
    pub fn record(&mut self, key: &str, energy_uj: f64, time_us: u64) {
        let t = self.entry(key);
        t.count += 1;
        t.energy_uj += energy_uj;
        t.time_us += time_us;
        self.total_uj += energy_uj;
    }

    /// Record a completed action primitive.
    pub fn record_action(&mut self, a: Action, energy_uj: f64, time_us: u64) {
        self.record(a.name(), energy_uj, time_us);
    }

    /// Record an aborted (rolled-back) attempt: the energy is burned but
    /// the work is discarded.
    pub fn record_abort(&mut self, a: Action, wasted_uj: f64) {
        let t = self.entry(a.name());
        t.aborted += 1;
        t.wasted_uj += wasted_uj;
        self.total_uj += wasted_uj;
    }

    /// Append a cumulative-energy sample at simulated time `t_us`.
    pub fn sample(&mut self, t_us: u64) {
        self.series.push((t_us, self.total_uj));
    }

    /// Total energy spent, µJ (including waste).
    pub fn total_uj(&self) -> f64 {
        self.total_uj
    }

    /// Tally for a key ("sense", "learn", "planner", "select:klast", ...).
    pub fn tally(&self, key: &str) -> ActionTally {
        self.per_action.get(key).copied().unwrap_or_default()
    }

    /// All tallies in key order.
    pub fn tallies(&self) -> impl Iterator<Item = (&str, &ActionTally)> {
        self.per_action.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// Overhead fraction of one key relative to total energy.
    pub fn fraction(&self, key: &str) -> f64 {
        if self.total_uj <= 0.0 {
            return 0.0;
        }
        self.tally(key).energy_uj / self.total_uj
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tallies_accumulate() {
        let mut m = EnergyMeter::new();
        m.record_action(Action::Learn, 9_309.0, 1_551_000);
        m.record_action(Action::Learn, 9_309.0, 1_551_000);
        m.record_action(Action::Infer, 63.2, 9_470);
        let learn = m.tally("learn");
        assert_eq!(learn.count, 2);
        assert!((learn.energy_uj - 18_618.0).abs() < 1e-9);
        assert!((m.total_uj() - 18_681.2).abs() < 1e-9);
    }

    #[test]
    fn aborts_count_as_waste() {
        let mut m = EnergyMeter::new();
        m.record_abort(Action::Learn, 1_000.0);
        assert_eq!(m.tally("learn").aborted, 1);
        assert_eq!(m.tally("learn").count, 0);
        assert_eq!(m.total_uj(), 1_000.0);
    }

    #[test]
    fn series_is_monotonic() {
        let mut m = EnergyMeter::new();
        for t in 0..10u64 {
            m.record("sense", 10.0, 5);
            m.sample(t * 100);
        }
        for w in m.series.windows(2) {
            assert!(w[1].1 >= w[0].1 && w[1].0 >= w[0].0);
        }
    }

    #[test]
    fn from_parts_round_trips_every_tally() {
        let mut m = EnergyMeter::new();
        m.record_action(Action::Learn, 9_309.0, 1_551_000);
        m.record_abort(Action::Sense, 40.0);
        m.record("planner", 57.0, 4_300);
        m.sample(100);
        let tallies: Vec<(String, ActionTally)> =
            m.tallies().map(|(k, t)| (k.to_string(), *t)).collect();
        let back = EnergyMeter::from_parts(tallies, m.series.clone(), m.total_uj());
        assert_eq!(back.total_uj(), m.total_uj());
        assert_eq!(back.series, m.series);
        for (k, t) in m.tallies() {
            assert_eq!(back.tally(k), *t, "{k}");
        }
    }

    #[test]
    fn fraction_of_overhead() {
        let mut m = EnergyMeter::new();
        m.record("planner", 57.0, 4_300);
        m.record_action(Action::Learn, 5_417.0, 953_600);
        let f = m.fraction("planner");
        assert!((f - 57.0 / 5_474.0).abs() < 1e-9);
    }
}
