//! Per-action energy/time cost model, calibrated to the paper's own
//! EnergyTrace measurements on the MSP430FR5994 (Figs. 16 and 17).
//!
//! The paper reports (k-NN, air quality): learn 9.309 mJ / 1551 ms split
//! into 3 sub-actions, sense 3.8 mJ, extract 151 ms, infer 64.98 ms; and
//! (NN-k-means, vibration): learn 5.417 mJ / 953.6 ms, sense 3.62 mJ,
//! extract 2.26 mJ, infer 63.2 µJ / 9.47 ms. Overheads: dynamic action
//! planner 57 µJ / 4.3 ms; k-last lists 270 µJ, randomized 1.8 µJ.
//! Values the paper does not state explicitly (e.g. energy of k-NN
//! extract) are interpolated from the stated time × the platform's active
//! power and marked `// interpolated`.

use crate::actions::Action;

/// Cost of executing one action to completion.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ActionCost {
    /// Total energy, µJ.
    pub energy_uj: f64,
    /// Total execution time, µs.
    pub time_us: u64,
    /// Number of atomic sub-actions the action is split into (§3.4).
    /// Energy/time are divided evenly across sub-actions.
    pub splits: u32,
}

impl ActionCost {
    pub const fn new(energy_uj: f64, time_us: u64, splits: u32) -> Self {
        ActionCost {
            energy_uj,
            time_us,
            splits,
        }
    }

    /// Energy of one sub-action, µJ.
    pub fn sub_energy_uj(&self) -> f64 {
        self.energy_uj / self.splits as f64
    }

    /// Time of one sub-action, µs.
    pub fn sub_time_us(&self) -> u64 {
        self.time_us / self.splits as u64
    }
}

/// The full cost table for one application/algorithm pairing.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub name: &'static str,
    costs: [ActionCost; 10],
    /// Dynamic action planner overhead per invocation (Fig. 17).
    pub planner: ActionCost,
    /// Example-selection heuristic overheads (Fig. 17).
    pub sel_round_robin: ActionCost,
    pub sel_k_last: ActionCost,
    pub sel_randomized: ActionCost,
    /// Energy charged per byte of checkpoint NVM traffic, µJ/B (FRAM
    /// writes on the paper's MSP430FR5994 cost on the order of nJ/byte).
    /// Default 0 keeps the calibrated per-action tables authoritative —
    /// the paper's learn costs already include a full-model checkpoint;
    /// set it non-zero to charge the *actual* (delta-sized) checkpoint
    /// traffic instead, which the engine meters as `nvm_ckpt`.
    pub nvm_uj_per_byte: f64,
}

impl CostModel {
    fn idx(a: Action) -> usize {
        Action::ALL.iter().position(|&x| x == a).unwrap()
    }

    /// Cost of an action.
    pub fn cost(&self, a: Action) -> ActionCost {
        self.costs[Self::idx(a)]
    }

    /// Override one action's cost (pre-inspection "split until it fits").
    pub fn set_cost(&mut self, a: Action, c: ActionCost) {
        self.costs[Self::idx(a)] = c;
    }

    /// k-NN cost table (air-quality app, Fig. 16(a)(b)).
    pub fn knn() -> Self {
        let costs = [
            // sense: 3 air-quality sensors, 3.8 mJ (paper)
            ActionCost::new(3_800.0, 920_000, 2),
            // extract: 151 ms (paper); energy interpolated @ ~6 mW active
            ActionCost::new(900.0, 151_000, 1),
            // decide: trivial branch
            ActionCost::new(12.0, 900, 1),
            // select: heuristic cost added separately; base bookkeeping
            ActionCost::new(20.0, 1_500, 1),
            // learnable: buffer-count check
            ActionCost::new(8.0, 600, 1),
            // learn: 9.309 mJ / 1551 ms, split into 3 (paper Fig. 16)
            ActionCost::new(9_309.0, 1_551_000, 3),
            // evaluate: score table scan
            ActionCost::new(60.0, 4_500, 1),
            // infer: 64.98 ms (paper); energy interpolated
            ActionCost::new(400.0, 64_980, 1),
            // tx: radio a ~8.5 KB k-NN ring snapshot over a BLE-class link
            // (~1 Mb/s payload rate at ~25 mW tx draw) — interpolated; the
            // paper prices no radio, but Intelligence-Beyond-the-Edge-style
            // deployments must budget it like any other action
            ActionCost::new(2_200.0, 85_000, 1),
            // rx: same airtime, lower rx draw // interpolated
            ActionCost::new(1_700.0, 85_000, 1),
        ];
        CostModel {
            name: "knn",
            costs,
            planner: ActionCost::new(57.0, 4_300, 1),
            sel_round_robin: ActionCost::new(9.0, 700, 1),
            sel_k_last: ActionCost::new(270.0, 21_000, 1),
            sel_randomized: ActionCost::new(1.8, 140, 1),
            nvm_uj_per_byte: 0.0,
        }
    }

    /// NN-k-means cost table (vibration app, Fig. 16(c)(d)).
    pub fn kmeans() -> Self {
        let costs = [
            // sense: 50 Hz accel window, 3.62 mJ (paper)
            ActionCost::new(3_620.0, 870_000, 2),
            // extract: 2.26 mJ (paper)
            ActionCost::new(2_260.0, 148_000, 1),
            ActionCost::new(12.0, 900, 1),
            ActionCost::new(20.0, 1_500, 1),
            ActionCost::new(8.0, 600, 1),
            // learn: 5.417 mJ / 953.6 ms (paper), split into 2 layers
            ActionCost::new(5_417.0, 953_600, 2),
            ActionCost::new(60.0, 4_500, 1),
            // infer: 63.2 µJ / 9.47 ms (paper)
            ActionCost::new(63.2, 9_470, 1),
            // tx/rx: the NN-k-means snapshot is ~0.4 KB (two centroid rows
            // + votes) — one short radio burst // interpolated
            ActionCost::new(160.0, 9_000, 1),
            ActionCost::new(120.0, 9_000, 1),
        ];
        CostModel {
            name: "kmeans",
            costs,
            planner: ActionCost::new(57.0, 4_300, 1),
            sel_round_robin: ActionCost::new(9.0, 700, 1),
            sel_k_last: ActionCost::new(270.0, 21_000, 1),
            sel_randomized: ActionCost::new(1.8, 140, 1),
            nvm_uj_per_byte: 0.0,
        }
    }

    /// RSSI-presence cost table: k-NN-like but with a cheap RF sense
    /// (RSSI sampling costs far less than the air-quality sensor trio)
    /// and faster cadence (§6.2: updates between tens of ms and seconds).
    pub fn knn_rssi() -> Self {
        let mut m = CostModel::knn();
        m.name = "knn_rssi";
        m.set_cost(Action::Sense, ActionCost::new(420.0, 90_000, 1));
        m.set_cost(Action::Extract, ActionCost::new(300.0, 45_000, 1));
        m.set_cost(Action::Learn, ActionCost::new(4_200.0, 640_000, 3));
        m.set_cost(Action::Infer, ActionCost::new(180.0, 26_000, 1));
        m
    }

    /// Total energy of the canonical full learn path
    /// (sense→extract→decide→select→learnable→learn→evaluate), µJ.
    pub fn learn_path_uj(&self) -> f64 {
        [
            Action::Sense,
            Action::Extract,
            Action::Decide,
            Action::Select,
            Action::Learnable,
            Action::Learn,
            Action::Evaluate,
        ]
        .iter()
        .map(|&a| self.cost(a).energy_uj)
        .sum()
    }

    /// Total energy of the infer path (sense→extract→decide→infer), µJ.
    pub fn infer_path_uj(&self) -> f64 {
        [Action::Sense, Action::Extract, Action::Decide, Action::Infer]
            .iter()
            .map(|&a| self.cost(a).energy_uj)
            .sum()
    }

    /// Energy (µJ) and time (µs) of one fleet sync exchange: one `tx` of
    /// the local model snapshot plus `rx_peers` received snapshots
    /// (1 for gossip, fleet size − 1 for all-reduce). The fleet round
    /// scheduler gates participation on this price — a shard whose
    /// capacitor cannot cover it skips the round, the paper's
    /// learn-or-discard energy gating lifted to the fleet tier.
    pub fn sync_price(&self, rx_peers: u32) -> (f64, u64) {
        let tx = self.cost(Action::Tx);
        let rx = self.cost(Action::Rx);
        (
            tx.energy_uj + rx.energy_uj * f64::from(rx_peers),
            tx.time_us + rx.time_us * u64::from(rx_peers),
        )
    }

    /// [`CostModel::sync_price`] with the `tx` leg scaled to the actual
    /// payload: the calibrated `Tx` cost prices a *full* model snapshot
    /// (`tx_full_bytes` on the wire), so a delta snapshot of `tx_bytes`
    /// pays `tx_bytes / tx_full_bytes` of it — airtime and radio energy
    /// shrink together, the wire analog of the O(dirty) NVM delta
    /// checkpoint. A payload at (or somehow above) the full size pays
    /// exactly the calibrated price: the scale factor is exactly 1.0, so
    /// full-snapshot fleets are float-bit-identical to the unscaled
    /// [`CostModel::sync_price`]. The `rx` legs stay at full price — a
    /// receiver budgets the whole listen window, not the bytes that
    /// happen to arrive.
    pub fn sync_price_bytes(
        &self,
        rx_peers: u32,
        tx_bytes: usize,
        tx_full_bytes: usize,
    ) -> (f64, u64) {
        let tx = self.cost(Action::Tx);
        let rx = self.cost(Action::Rx);
        let scale = if tx_bytes < tx_full_bytes && tx_full_bytes > 0 {
            tx_bytes as f64 / tx_full_bytes as f64
        } else {
            1.0
        };
        (
            tx.energy_uj * scale + rx.energy_uj * f64::from(rx_peers),
            (tx.time_us as f64 * scale).round() as u64 + rx.time_us * u64::from(rx_peers),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knn_matches_paper_headline_numbers() {
        let m = CostModel::knn();
        assert_eq!(m.cost(Action::Learn).energy_uj, 9_309.0);
        assert_eq!(m.cost(Action::Learn).time_us, 1_551_000);
        assert_eq!(m.cost(Action::Sense).energy_uj, 3_800.0);
        assert_eq!(m.cost(Action::Infer).time_us, 64_980);
        assert_eq!(m.planner.energy_uj, 57.0);
    }

    #[test]
    fn kmeans_learn_100x_infer() {
        // paper: learn overhead ~100x infer for the NN k-means
        let m = CostModel::kmeans();
        let ratio = m.cost(Action::Learn).energy_uj / m.cost(Action::Infer).energy_uj;
        assert!((60.0..120.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn selection_heuristic_ordering() {
        // k-last >> round-robin > randomized (Fig. 17)
        let m = CostModel::kmeans();
        assert!(m.sel_k_last.energy_uj > 10.0 * m.sel_round_robin.energy_uj);
        assert!(m.sel_round_robin.energy_uj > m.sel_randomized.energy_uj);
    }

    #[test]
    fn sub_action_split_divides_cost() {
        let c = ActionCost::new(9_000.0, 1_500_000, 3);
        assert_eq!(c.sub_energy_uj(), 3_000.0);
        assert_eq!(c.sub_time_us(), 500_000);
    }

    #[test]
    fn learn_path_dominates_infer_path() {
        for m in [CostModel::knn(), CostModel::kmeans(), CostModel::knn_rssi()] {
            assert!(m.learn_path_uj() > m.infer_path_uj(), "{}", m.name);
        }
    }

    #[test]
    fn radio_entries_are_priced_and_scale_with_peers() {
        for m in [CostModel::knn(), CostModel::kmeans(), CostModel::knn_rssi()] {
            let tx = m.cost(Action::Tx);
            let rx = m.cost(Action::Rx);
            assert!(tx.energy_uj > 0.0 && rx.energy_uj > 0.0, "{}", m.name);
            // a sync exchange costs less than a learn (otherwise syncing
            // would never be worth scheduling) but is never free
            let (gossip_uj, gossip_us) = m.sync_price(1);
            assert_eq!(gossip_uj, tx.energy_uj + rx.energy_uj);
            assert_eq!(gossip_us, tx.time_us + rx.time_us);
            assert!(gossip_uj < m.cost(Action::Learn).energy_uj, "{}", m.name);
            // all-reduce in a 16-shard fleet receives 15 snapshots
            let (ar_uj, ar_us) = m.sync_price(15);
            assert_eq!(ar_uj, tx.energy_uj + 15.0 * rx.energy_uj);
            assert!(ar_us > gossip_us);
        }
    }

    #[test]
    fn byte_scaled_sync_price_shrinks_tx_and_keeps_full_exact() {
        for m in [CostModel::knn(), CostModel::kmeans(), CostModel::knn_rssi()] {
            // a full payload pays exactly the unscaled price, bit for bit
            for peers in [0u32, 1, 15] {
                assert_eq!(
                    m.sync_price_bytes(peers, 8_980, 8_980),
                    m.sync_price(peers),
                    "{}",
                    m.name
                );
                // degenerate full size: no scaling either
                assert_eq!(m.sync_price_bytes(peers, 0, 0), m.sync_price(peers));
            }
            // a quarter payload pays a quarter of the tx leg only
            let tx = m.cost(Action::Tx);
            let rx = m.cost(Action::Rx);
            let (uj, us) = m.sync_price_bytes(1, 2_245, 8_980);
            assert!((uj - (tx.energy_uj * 0.25 + rx.energy_uj)).abs() < 1e-9);
            assert_eq!(us, (tx.time_us as f64 * 0.25).round() as u64 + rx.time_us);
            let (full_uj, full_us) = m.sync_price(1);
            assert!(uj < full_uj && us < full_us, "{}", m.name);
        }
    }
}
