//! Energy subsystem: harvesters, capacitor storage, per-action cost model,
//! run-time energy metering, and the energy pre-inspection tool.
//!
//! Substitution note (DESIGN.md §1): the paper uses physical harvesters
//! (solar panel, Powercast P2110 RF, PPA-2014 piezo) and TI EnergyTrace;
//! here every element is a calibrated simulator. The per-action energy
//! constants in [`cost`] are taken from the paper's own measurements
//! (Figs. 16–17), so energy-efficiency *ratios* are preserved.

pub mod capacitor;
pub mod cost;
pub mod harvester;
pub mod inspect;
pub mod meter;

pub use capacitor::Capacitor;
pub use cost::{ActionCost, CostModel};
pub use harvester::{Harvester, HarvesterKind};
pub use meter::EnergyMeter;
