//! # ilearn — Intermittent Learning on intermittently powered systems
//!
//! A full reproduction of *"Intermittent Learning: On-Device Machine
//! Learning on Intermittently Powered Systems"* (Lee, Islam, Luo, Nirjon —
//! Proc. ACM IMWUT 3(4):141, 2019) as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! * **L3 (this crate)** — the intermittent-execution coordinator: energy
//!   harvesters and capacitor storage ([`energy`]), the non-volatile memory
//!   model with action atomicity ([`nvm`]), the eight action primitives and
//!   their state diagram ([`actions`]), the dynamic action planner
//!   ([`planner`]), the example-selection heuristics ([`selection`]), the
//!   on-device learners ([`learning`]), the discrete-event intermittent
//!   engine ([`sim`]), the three paper applications ([`apps`]), the
//!   intermittent-computing and offline-ML baselines ([`baselines`]) and
//!   the full evaluation harness ([`eval`]).
//! * **L2 (python/compile/model.py)** — the numeric payload of each action
//!   (k-NN anomaly scoring, competitive-learning k-means, feature
//!   extraction) as jitted JAX functions, AOT-lowered once to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the compute
//!   hot-spots, pinned to a pure-jnp oracle by pytest.
//!
//! The [`runtime`] module loads the AOT artifacts via the PJRT C API and
//! the [`backend`] module lets every learner run either on the PJRT
//! executables (proving the three layers compose) or on a pure-rust native
//! implementation of the same math (float-tolerance compatible, used for
//! large simulation sweeps).
//!
//! Python never runs on the request path: `make artifacts` is a build-time
//! step and the `ilearn` binary is self-contained afterwards.

pub mod actions;
pub mod apps;
pub mod backend;
pub mod baselines;
pub mod energy;
pub mod error;
pub mod eval;
pub mod learning;
pub mod nvm;
pub mod planner;
pub mod runtime;
pub mod selection;
pub mod sensors;
pub mod sim;
pub mod util;

pub use error::{Error, Result};
