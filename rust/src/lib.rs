//! # ilearn — Intermittent Learning on intermittently powered systems
//!
//! A full reproduction of *"Intermittent Learning: On-Device Machine
//! Learning on Intermittently Powered Systems"* (Lee, Islam, Luo, Nirjon —
//! Proc. ACM IMWUT 3(4):141, 2019) as a three-layer Rust + JAX + Pallas
//! stack, organized around a declarative **scenario API**:
//!
//! * **L3 (this crate)** — the intermittent-execution coordinator: energy
//!   harvesters and capacitor storage ([`energy`]), the non-volatile memory
//!   model with action atomicity ([`nvm`]), the eight action primitives and
//!   their state diagram ([`actions`]), the dynamic action planner
//!   ([`planner`]), the example-selection heuristics ([`selection`]), the
//!   on-device learners ([`learning`]), the discrete-event intermittent
//!   engine ([`sim`] — split into World/Executor/Policy layers with an
//!   event-driven charge kernel; see `ARCHITECTURE.md`), the
//!   intermittent-computing and offline-ML baselines ([`baselines`]), the
//!   full evaluation harness ([`eval`]) and the intermittent-safety
//!   analyzer ([`analysis`] — access-trace linting of every checkpoint
//!   path for WAR/atomicity/delta/parity hazards, `ilearn analyze`).
//! * **L2 (python/compile/model.py)** — the numeric payload of each action
//!   (k-NN anomaly scoring, competitive-learning k-means, feature
//!   extraction) as jitted JAX functions, AOT-lowered once to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the compute
//!   hot-spots, pinned to a pure-jnp oracle by pytest.
//!
//! ## Scenario API
//!
//! Experiment construction is data, not code. A
//! [`scenario::ScenarioSpec`] names every part of a device world —
//! harvester, capacitor, sensor, cost model, learner, goal, scheduler,
//! selection heuristic, backend, horizon, seed — validates, round-trips
//! through JSON, and compiles into a runnable engine via the typed
//! [`sim::engine::EngineBuilder`]. The three paper applications are named
//! presets ([`scenario::preset`]; [`apps`] is a thin veneer over them),
//! and [`scenario::SweepSpec`] expands (scenarios × schedulers ×
//! heuristics × backends × seeds) grids that a [`scenario::SweepRunner`]
//! executes across worker threads — one engine per thread, since compute
//! backends are deliberately not `Send` — emitting one JSON document per
//! cell. A scenario's `"fleet"` block ([`scenario::FleetSpec`]) deploys
//! it across N shards ([`sim::fleet`]): per-shard worlds with jittered
//! harvester phases and strided seeds, shard-level work items on the
//! sweep pool, and fan-in rollups ([`sim::fleet::FleetResult`]). The
//! fleet's optional `"sync"` block ([`scenario::SyncSpec`]) turns the
//! fan-out into a round-based **federated** simulation: shards pause at
//! periodic boundaries, exchange learner snapshots under a radio energy
//! gate (`Action::{Tx, Rx}` priced per cost model; a shard that cannot
//! afford the exchange skips the round) and merge
//! ([`learning::ModelSnapshot`], [`learning::Learner::merge`]). The
//! `ilearn` CLI exposes this as `run [--spec file.json]`,
//! `fleet <scenario> --shards N [--sync-period-us P]` and
//! `sweep grid.json`.
//!
//! ## Backends
//!
//! The [`runtime`] module loads AOT artifacts via the PJRT C API and the
//! [`backend`] module lets every learner run either on the PJRT
//! executables (proving the three layers compose; `pjrt` cargo feature)
//! or on a pure-rust native implementation of the same math
//! (float-tolerance compatible, used for large simulation sweeps; the
//! default build is pure rust).
//!
//! Python never runs on the request path: `make artifacts` is a build-time
//! step and the `ilearn` binary is self-contained afterwards.

pub mod actions;
pub mod analysis;
pub mod apps;
pub mod backend;
pub mod baselines;
pub mod energy;
pub mod error;
pub mod eval;
pub mod fault;
pub mod learning;
pub mod nvm;
pub mod planner;
pub mod runtime;
pub mod scenario;
pub mod selection;
pub mod sensors;
pub mod sim;
pub mod util;

pub use error::{Error, Result};
