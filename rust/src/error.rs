//! Crate-wide error type.

/// Errors surfaced by the ilearn library.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// PJRT / XLA runtime failures (artifact loading, compile, execute).
    #[error("runtime: {0}")]
    Runtime(String),

    /// An AOT artifact is missing or its manifest disagrees with the
    /// buffer shapes the caller supplied.
    #[error("artifact `{name}`: {msg}")]
    Artifact { name: String, msg: String },

    /// Configuration / CLI parsing problems.
    #[error("config: {0}")]
    Config(String),

    /// An action was requested that the action state diagram forbids from
    /// the example's current state.
    #[error("illegal action transition: {from:?} -> {to:?}")]
    IllegalTransition {
        from: crate::actions::Action,
        to: crate::actions::Action,
    },

    /// Energy pre-inspection rejected an action (exceeds the budget the
    /// capacitor can deliver in one wake cycle).
    #[error("energy pre-inspection: action `{action}` needs {needed_uj:.1} uJ > budget {budget_uj:.1} uJ")]
    EnergyBudget {
        action: String,
        needed_uj: f64,
        budget_uj: f64,
    },

    /// NVM access errors (unknown variable, size mismatch).
    #[error("nvm: {0}")]
    Nvm(String),

    /// An injected power failure tripped ([`crate::fault::FaultInjector`]):
    /// the device is dead until the host reboots it via
    /// [`crate::nvm::Nvm::power_failure_reset`]. Every NVM operation after
    /// the trip surfaces this error without mutating the store, so the
    /// torn durable state is preserved exactly for crash-recovery checks.
    #[error("power cut (fault injection)")]
    PowerCut,

    /// I/O wrapper.
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
