//! Descriptive statistics shared by sensors, learners and the evaluator.

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() as f32 / xs.len() as f32
}

/// Population standard deviation (matches `jnp.std` and the L1 kernel).
pub fn std(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs) as f64;
    let var = xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / xs.len() as f64;
    var.max(0.0).sqrt() as f32
}

/// Median; for even lengths the mean of the two middle values (matches the
/// L1 features kernel).
pub fn median(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Root mean square.
pub fn rms(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let s = xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>();
    (s / xs.len() as f64).sqrt() as f32
}

/// Peak-to-peak amplitude (max − min).
pub fn p2p(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &x in xs {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    hi - lo
}

/// Zero-crossing rate of the mean-removed signal, normalized to [0, 1]
/// (fraction of consecutive pairs that cross zero) — matches the kernel.
pub fn zcr(xs: &[f32]) -> f32 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let sign = |x: f32| if x - m >= 0.0 { 1.0f32 } else { -1.0 };
    let crossings: f32 = xs
        .windows(2)
        .map(|w| (sign(w[1]) - sign(w[0])).abs())
        .sum::<f32>()
        / 2.0;
    crossings / (xs.len() - 1) as f32
}

/// Average absolute variation, mean |x_t − x_{t−1}| (paper's AAV).
pub fn aav(xs: &[f32]) -> f32 {
    if xs.len() < 2 {
        return 0.0;
    }
    xs.windows(2).map(|w| (w[1] - w[0]).abs()).sum::<f32>() / (xs.len() - 1) as f32
}

/// Mean absolute value.
pub fn mav(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x.abs() as f64).sum::<f64>() as f32 / xs.len() as f32
}

/// q-th percentile (0..=1) using the paper's rule: the value at index
/// ceil(q·n) − 1 of the ascending sort (matches the L2 `knn_learn` HLO).
pub fn percentile(xs: &[f32], q: f64) -> f32 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    percentile_sorted(&v, q)
}

/// Same rule over an already-ascending-sorted slice (no clone — the
/// learn hot path sorts a reused scratch and calls this).
pub fn percentile_sorted(sorted: &[f32], q: f64) -> f32 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((q * sorted.len() as f64).ceil() as usize).max(1) - 1;
    sorted[idx.min(sorted.len() - 1)]
}

/// Euclidean distance between two feature vectors (paper §6.1).
pub fn euclidean(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0f64;
    for i in 0..a.len() {
        let d = (a[i] - b[i]) as f64;
        s += d * d;
    }
    s.sqrt() as f32
}

/// Squared Euclidean distance (avoids the sqrt on hot paths).
pub fn sq_euclidean(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0f64;
    for i in 0..a.len() {
        let d = (a[i] - b[i]) as f64;
        s += d * d;
    }
    s as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-6);
        assert!((std(&xs) - 1.118034).abs() < 1e-5);
        assert!((median(&xs) - 2.5).abs() < 1e-6);
        assert!((rms(&xs) - 2.7386127).abs() < 1e-5);
        assert!((p2p(&xs) - 3.0).abs() < 1e-6);
    }

    #[test]
    fn median_odd() {
        assert_eq!(median(&[5.0, 1.0, 3.0]), 3.0);
    }

    #[test]
    fn zcr_alternating() {
        let xs = [1.0, -1.0, 1.0, -1.0, 1.0, -1.0];
        assert!((zcr(&xs) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn zcr_constant_is_zero() {
        assert_eq!(zcr(&[2.0; 16]), 0.0);
    }

    #[test]
    fn aav_ramp() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        assert!((aav(&xs) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn percentile_matches_paper_rule() {
        let xs: Vec<f32> = (1..=40).map(|i| i as f32).collect();
        // ceil(0.9*40)-1 = 35 -> value 36
        assert_eq!(percentile(&xs, 0.9), 36.0);
        assert_eq!(percentile(&xs, 1.0), 40.0);
        assert_eq!(percentile(&[7.0], 0.9), 7.0);
        // the sorted variant is the same rule (xs is already ascending)
        assert_eq!(percentile_sorted(&xs, 0.9), percentile(&xs, 0.9));
        assert_eq!(percentile_sorted(&[], 0.9), 0.0);
    }

    #[test]
    fn euclidean_matches_hand() {
        assert!((euclidean(&[0.0, 3.0], &[4.0, 0.0]) - 5.0).abs() < 1e-6);
        assert!((sq_euclidean(&[0.0, 3.0], &[4.0, 0.0]) - 25.0).abs() < 1e-6);
    }

    #[test]
    fn empty_slices_are_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(rms(&[]), 0.0);
        assert_eq!(p2p(&[]), 0.0);
        assert_eq!(percentile(&[], 0.9), 0.0);
    }
}
