//! Deterministic PRNG: PCG-XSH-RR 64/32 (O'Neill 2014).
//!
//! Every stochastic component of the simulator (harvester noise, sensor
//! generators, the randomized-choice heuristic, property tests) draws from
//! an explicitly seeded [`Rng`], so every experiment in EXPERIMENTS.md is
//! reproducible bit-for-bit.

/// PCG-XSH-RR 64/32 generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
}

const MULT: u64 = 6364136223846793005;

impl Rng {
    /// Create a generator from a seed and a stream id. Distinct stream ids
    /// give statistically independent sequences for the same seed, which
    /// the simulator uses to decouple e.g. harvester noise from sensor
    /// noise (changing one must not perturb the other).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Rng {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Create a generator from a bare seed (stream 0).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random bits / 2^53
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Uses Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u32) as usize
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (polar form, one value per call).
    pub fn gaussian(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Normal with given mean/std.
    #[inline]
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gaussian()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Rng::with_stream(42, 1);
        let mut b = Rng::with_stream(42, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let mut counts = [0u32; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }
}
