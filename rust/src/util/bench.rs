//! Micro-benchmark harness (criterion is not in the offline vendor set).
//!
//! Measures wall-clock with warmup, reports mean / p50 / p95 / min over a
//! fixed iteration budget, and prevents dead-code elimination with a
//! `black_box`. Used by every binary in `benches/`.

use std::hint::black_box as std_black_box;
use std::time::Instant;

/// Re-export of `std::hint::black_box` under the name bench code expects.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Result of one measured benchmark.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl Measurement {
    /// Render one human-readable row (also machine-greppable).
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p95 {:>12}  min {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            fmt_ns(self.min_ns),
        )
    }
}

/// Pretty-print nanoseconds with an adaptive unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} us", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Benchmark `f`, auto-scaling the iteration count so total measured time
/// is ~`budget_ms` milliseconds (after a 10% warmup).
pub fn bench<F: FnMut()>(name: &str, budget_ms: u64, mut f: F) -> Measurement {
    // Calibrate: run until 5ms or 100 iterations to estimate per-iter cost.
    let cal_start = Instant::now();
    let mut cal_iters = 0usize;
    while cal_start.elapsed().as_millis() < 5 && cal_iters < 100 {
        f();
        cal_iters += 1;
    }
    let per_iter = cal_start.elapsed().as_nanos() as f64 / cal_iters.max(1) as f64;
    let budget_ns = (budget_ms as f64) * 1e6;
    let iters = ((budget_ns / per_iter.max(1.0)) as usize).clamp(10, 1_000_000);

    // Warmup 10%.
    for _ in 0..(iters / 10).max(1) {
        f();
    }

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let pct = |q: f64| samples[((q * samples.len() as f64) as usize).min(samples.len() - 1)];
    Measurement {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: pct(0.50),
        p95_ns: pct(0.95),
        min_ns: samples[0],
    }
}

/// Run a one-shot timed section (for end-to-end figure benches where a
/// single run is already seconds long).
pub fn time_once<T, F: FnOnce() -> T>(name: &str, f: F) -> (T, Measurement) {
    let t = Instant::now();
    let out = f();
    let ns = t.elapsed().as_nanos() as f64;
    (
        out,
        Measurement {
            name: name.to_string(),
            iters: 1,
            mean_ns: ns,
            p50_ns: ns,
            p95_ns: ns,
            min_ns: ns,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let m = bench("noop-ish", 5, || {
            black_box(2u64.wrapping_mul(3));
        });
        assert!(m.iters >= 10);
        assert!(m.min_ns <= m.p50_ns && m.p50_ns <= m.p95_ns);
        assert!(m.mean_ns > 0.0);
    }

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(2_500.0), "2.50 us");
        assert_eq!(fmt_ns(2_500_000.0), "2.50 ms");
        assert_eq!(fmt_ns(2_500_000_000.0), "2.500 s");
    }
}
