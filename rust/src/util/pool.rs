//! The shared claim-counter worker pool.
//!
//! One lock-free work-distribution primitive serves every fan-out in the
//! crate: sweep cells ([`crate::scenario::sweep`]), fleet shards
//! ([`crate::sim::fleet`]) and the shard-level work items a sweep cell
//! expands into. Work is claimed through an atomic counter (no queue, no
//! mutex) and every finished item lands in its own result slot through a
//! per-index channel send, so big grids never contend on a shared
//! collection and results come back in input order for any worker count.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

/// Worker-thread count `threads` resolves to for `n` jobs
/// (`0` = available parallelism, always clamped to the job count).
pub fn resolve_workers(threads: usize, n: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(4)
    } else {
        threads
    }
    .min(n.max(1))
}

/// Run `job(0..n)` across `threads` workers (0 = available parallelism)
/// and return the results in index order, identical for any thread count.
///
/// The job closure builds whatever per-item state it needs on the worker
/// thread — engines are constructed there because compute backends are
/// deliberately not `Send` — and only the (Send) results travel back.
pub fn run_indexed<T, F>(n: usize, threads: usize, job: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let workers = resolve_workers(threads, n);
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            let next = &next;
            let job = &job;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                if tx.send((i, job(i))).is_err() {
                    break; // receiver gone: nothing left to report to
                }
            });
        }
        drop(tx); // workers hold the remaining senders
    });
    // every worker has exited, so the channel is closed and fully drained
    let mut results: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for (i, r) in rx {
        results[i] = Some(r);
    }
    results
        .into_iter()
        .map(|r| r.expect("every claimed item reports exactly once"))
        .collect()
}

/// Streaming variant of [`run_indexed`]: results are folded on the
/// calling thread in strict index order *while* the workers run, and
/// then dropped — nothing is retained per item, so a million-item
/// fan-out costs O(workers) memory instead of O(n).
///
/// Each worker owns a lane state built by `init()` on the worker thread
/// (it never crosses threads, so it may hold non-`Send` resources such
/// as compute backends or pooled NVM slabs) and threads it through every
/// item it claims. The coordinator holds out-of-order arrivals in a
/// reorder buffer and calls `fold(i, result)` exactly once per index, in
/// ascending index order — the same fold sequence a serial loop would
/// produce, for any worker count. The buffer only holds results that
/// arrived ahead of the next expected index, so it stays O(workers) in
/// practice.
pub fn fold_indexed<S, T, I, F, G>(n: usize, threads: usize, init: I, job: F, mut fold: G)
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
    G: FnMut(usize, T),
{
    if n == 0 {
        return;
    }
    let workers = resolve_workers(threads, n);
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            let next = &next;
            let init = &init;
            let job = &job;
            scope.spawn(move || {
                let mut lane = init();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    if tx.send((i, job(&mut lane, i))).is_err() {
                        break; // receiver gone: nothing left to report to
                    }
                }
            });
        }
        drop(tx); // workers hold the remaining senders
        let mut hold: std::collections::BTreeMap<usize, T> = std::collections::BTreeMap::new();
        let mut want = 0usize;
        for (i, r) in rx {
            hold.insert(i, r);
            while let Some(r) = hold.remove(&want) {
                fold(want, r);
                want += 1;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_index_order_for_any_thread_count() {
        for threads in [1, 2, 0] {
            let out = run_indexed(17, threads, |i| i * i);
            assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn empty_input_spawns_nothing() {
        let out: Vec<u32> = run_indexed(0, 4, |_| unreachable!());
        assert!(out.is_empty());
    }

    #[test]
    fn fold_indexed_folds_in_strict_index_order_for_any_thread_count() {
        for threads in [1, 2, 0] {
            let mut seen = Vec::new();
            fold_indexed(
                17,
                threads,
                || 0u64, // lane state: items this worker has claimed
                |lane, i| {
                    *lane += 1;
                    (i * i, *lane)
                },
                |i, (sq, claimed)| {
                    assert!(claimed >= 1);
                    seen.push((i, sq));
                },
            );
            let want: Vec<_> = (0..17).map(|i| (i, i * i)).collect();
            assert_eq!(seen, want, "threads={threads}");
        }
    }

    #[test]
    fn fold_indexed_on_empty_input_never_calls_anything() {
        fold_indexed(
            0,
            4,
            || (),
            |_, _| unreachable!("no items to claim"),
            |_, ()| unreachable!("nothing to fold"),
        );
    }

    #[test]
    fn fold_indexed_lane_state_persists_across_claims() {
        // One worker claims all items, so its lane counter must reach n.
        let mut last = 0;
        fold_indexed(
            9,
            1,
            || 0usize,
            |lane, _| {
                *lane += 1;
                *lane
            },
            |_, c| last = last.max(c),
        );
        assert_eq!(last, 9);
    }

    #[test]
    fn workers_clamp_to_job_count() {
        assert_eq!(resolve_workers(8, 3), 3);
        assert_eq!(resolve_workers(2, 100), 2);
        assert!(resolve_workers(0, 100) >= 1);
        assert_eq!(resolve_workers(0, 0), 1);
    }
}
