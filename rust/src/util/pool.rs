//! The shared claim-counter worker pool.
//!
//! One lock-free work-distribution primitive serves every fan-out in the
//! crate: sweep cells ([`crate::scenario::sweep`]), fleet shards
//! ([`crate::sim::fleet`]) and the shard-level work items a sweep cell
//! expands into. Work is claimed through an atomic counter (no queue, no
//! mutex) and every finished item lands in its own result slot through a
//! per-index channel send, so big grids never contend on a shared
//! collection and results come back in input order for any worker count.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

/// Worker-thread count `threads` resolves to for `n` jobs
/// (`0` = available parallelism, always clamped to the job count).
pub fn resolve_workers(threads: usize, n: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(4)
    } else {
        threads
    }
    .min(n.max(1))
}

/// Run `job(0..n)` across `threads` workers (0 = available parallelism)
/// and return the results in index order, identical for any thread count.
///
/// The job closure builds whatever per-item state it needs on the worker
/// thread — engines are constructed there because compute backends are
/// deliberately not `Send` — and only the (Send) results travel back.
pub fn run_indexed<T, F>(n: usize, threads: usize, job: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let workers = resolve_workers(threads, n);
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            let next = &next;
            let job = &job;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                if tx.send((i, job(i))).is_err() {
                    break; // receiver gone: nothing left to report to
                }
            });
        }
        drop(tx); // workers hold the remaining senders
    });
    // every worker has exited, so the channel is closed and fully drained
    let mut results: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for (i, r) in rx {
        results[i] = Some(r);
    }
    results
        .into_iter()
        .map(|r| r.expect("every claimed item reports exactly once"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_index_order_for_any_thread_count() {
        for threads in [1, 2, 0] {
            let out = run_indexed(17, threads, |i| i * i);
            assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn empty_input_spawns_nothing() {
        let out: Vec<u32> = run_indexed(0, 4, |_| unreachable!());
        assert!(out.is_empty());
    }

    #[test]
    fn workers_clamp_to_job_count() {
        assert_eq!(resolve_workers(8, 3), 3);
        assert_eq!(resolve_workers(2, 100), 2);
        assert!(resolve_workers(0, 100) >= 1);
        assert_eq!(resolve_workers(0, 0), 1);
    }
}
