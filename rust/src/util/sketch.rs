//! Mergeable metric sketches for streaming fleet fan-in.
//!
//! A population-scale fleet folds each shard's scalar metrics into a
//! [`MetricSketch`] and drops the shard's `RunResult` — the fleet's
//! memory footprint is the sketch, not the population. The sketch keeps
//! an exact `n`/`min`/`max` plus a sparse base-2 log histogram (8
//! sub-buckets per octave), which answers quantile queries with a
//! bounded relative error of `1/(2*SUB)` = 6.25%.
//!
//! Everything the sketch stores is either an integer count or a
//! `min`/`max` fold, so merging two sketches — or folding values in any
//! order — produces the *identical* sketch: the structure is fully
//! order- and associativity-invariant. Deliberately absent are sums and
//! means: float addition is order-dependent, so those stay in the
//! fleet's index-ordered `Rollup` accumulators (`sim/fleet.rs`), which
//! reproduce the retained path's op order exactly.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Sub-buckets per power-of-two octave. Quantile estimates land in the
/// true value's bucket, whose width is `2^e / SUB`, so the midpoint
/// estimate is within `1/(2*SUB)` relative error.
const SUB: i64 = 8;

/// Synthetic bucket key for subnormal positives (below them all).
const KEY_SUBNORMAL: i64 = i64::MIN / 2;
/// Synthetic bucket key for `+inf` (above them all).
const KEY_INF: i64 = i64::MAX / 2;

/// Bucket key for a finite positive normal `v`: `exponent * SUB + sub`,
/// monotone in `v` (larger values always get larger keys).
fn bucket_of(v: f64) -> i64 {
    let e = ((v.to_bits() >> 52) & 0x7ff) as i64;
    if e == 0 {
        return KEY_SUBNORMAL;
    }
    if e == 0x7ff {
        return KEY_INF;
    }
    let exp = e - 1023;
    // Mantissa fraction in [1, 2): v / 2^exp, with 2^exp rebuilt from
    // the raw exponent bits (exact, no libm).
    let frac = v / f64::from_bits((e as u64) << 52);
    let sub = ((frac - 1.0) * SUB as f64) as i64;
    exp * SUB + sub.clamp(0, SUB - 1)
}

/// Midpoint of a bucket — the quantile estimate for any value in it.
fn bucket_mid(k: i64) -> f64 {
    if k == KEY_SUBNORMAL {
        return 0.0;
    }
    if k == KEY_INF {
        return f64::MAX;
    }
    let exp = k.div_euclid(SUB);
    let sub = k.rem_euclid(SUB);
    let base = 2f64.powi(exp as i32);
    let width = base / SUB as f64;
    base + sub as f64 * width + width / 2.0
}

/// Order-invariant streaming summary of one scalar metric: exact
/// count/min/max plus a sparse log2 histogram for quantiles.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricSketch {
    n: u64,
    min: f64,
    max: f64,
    /// Values counted as exactly zero (no log bucket exists for them).
    zeros: u64,
    /// Negative values, counted as a single mass at [`Self::min`] —
    /// fleet metrics are non-negative, this is a safety net.
    negatives: u64,
    /// Sparse log2 buckets for finite positives: key → count.
    bins: BTreeMap<i64, u64>,
}

impl Default for MetricSketch {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricSketch {
    pub fn new() -> Self {
        MetricSketch {
            n: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            zeros: 0,
            negatives: 0,
            bins: BTreeMap::new(),
        }
    }

    /// Fold one value in. Every update is a count increment or a
    /// min/max fold, so record order never changes the result.
    pub fn record(&mut self, v: f64) {
        self.n += 1;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        if v > 0.0 {
            *self.bins.entry(bucket_of(v)).or_insert(0) += 1;
        } else if v < 0.0 {
            self.negatives += 1;
        } else {
            // 0.0 (and NaN, which no fleet metric produces).
            self.zeros += 1;
        }
    }

    /// Fold another sketch in. `merge(a, b)` equals recording all of
    /// `b`'s values into `a` — in any order, grouped any way.
    pub fn merge(&mut self, other: &MetricSketch) {
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.zeros += other.zeros;
        self.negatives += other.negatives;
        for (&k, &c) in &other.bins {
            *self.bins.entry(k).or_insert(0) += c;
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// Exact minimum (0.0 when empty, matching the `Rollup` convention).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Exact maximum (0.0 when empty).
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Quantile estimate for `q` in [0, 1]: the value at rank
    /// `ceil(q * n)` (1-based), estimated as its bucket's midpoint and
    /// clamped to the exact `[min, max]`. Relative error is at most
    /// `1/(2*SUB)` = 6.25% for positive values; an empty sketch
    /// answers 0.0 and a singleton answers its value exactly (the
    /// clamp collapses to it).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let rank = ((q * self.n as f64).ceil() as u64).clamp(1, self.n);
        // The extreme ranks are known exactly — answer them exactly.
        if rank == 1 {
            return self.min;
        }
        if rank == self.n {
            return self.max;
        }
        let mut cum = self.negatives;
        if rank <= cum {
            return self.min;
        }
        cum += self.zeros;
        if rank <= cum {
            return 0.0;
        }
        for (&k, &c) in &self.bins {
            cum += c;
            if rank <= cum {
                return bucket_mid(k).clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n", Json::Num(self.n as f64)),
            ("min", Json::Num(self.min())),
            ("max", Json::Num(self.max())),
            ("p50", Json::Num(self.quantile(0.5))),
            ("p90", Json::Num(self.quantile(0.9))),
            ("p99", Json::Num(self.quantile(0.99))),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sketch_of(vals: &[f64]) -> MetricSketch {
        let mut s = MetricSketch::new();
        for &v in vals {
            s.record(v);
        }
        s
    }

    #[test]
    fn empty_sketch_is_all_zeros() {
        let s = MetricSketch::new();
        assert_eq!(s.count(), 0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
        assert_eq!(s.quantile(0.5), 0.0);
        assert_eq!(
            s.to_json().to_string(),
            "{\"n\":0,\"min\":0,\"max\":0,\"p50\":0,\"p90\":0,\"p99\":0}"
        );
    }

    #[test]
    fn singleton_sketch_answers_its_value_exactly() {
        let s = sketch_of(&[3.7]);
        assert_eq!(s.count(), 1);
        assert_eq!(s.min(), 3.7);
        assert_eq!(s.max(), 3.7);
        // Bucket midpoint clamped to [min, max] collapses to the value.
        for q in [0.0, 0.01, 0.5, 0.99, 1.0] {
            assert_eq!(s.quantile(q), 3.7, "q={q}");
        }
    }

    #[test]
    fn zeros_and_negatives_have_exact_answers() {
        let s = sketch_of(&[0.0, 0.0, -2.0, 5.0]);
        assert_eq!(s.min(), -2.0);
        assert_eq!(s.max(), 5.0);
        // Rank walk: negatives first, then zeros, then positives.
        assert_eq!(s.quantile(0.25), -2.0);
        assert_eq!(s.quantile(0.5), 0.0);
        assert_eq!(s.quantile(0.75), 0.0);
    }

    #[test]
    fn merge_is_order_and_grouping_invariant() {
        let mut rng = Rng::new(17);
        let vals: Vec<f64> = (0..300)
            .map(|_| rng.f64() * 1_000.0 + 0.001)
            .collect();
        let forward = sketch_of(&vals);

        // Reverse record order.
        let mut rev = vals.clone();
        rev.reverse();
        assert_eq!(forward, sketch_of(&rev));

        // Shuffled record order.
        let mut shuf = vals.clone();
        rng.shuffle(&mut shuf);
        assert_eq!(forward, sketch_of(&shuf));

        // Chunked sub-sketches merged back-to-front.
        let mut merged = MetricSketch::new();
        for chunk in vals.chunks(37).rev() {
            merged.merge(&sketch_of(chunk));
        }
        assert_eq!(forward, merged);

        // Unbalanced merge tree: ((a+b)+c) vs (a+(b+c)).
        let (a, b, c) = (
            sketch_of(&vals[..100]),
            sketch_of(&vals[100..200]),
            sketch_of(&vals[200..]),
        );
        let mut left = a.clone();
        left.merge(&b);
        left.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut right = a.clone();
        right.merge(&bc);
        assert_eq!(left, right);
        assert_eq!(left, forward);
    }

    #[test]
    fn quantile_error_is_within_the_log_bucket_bound() {
        let mut rng = Rng::new(7);
        // Values spanning ~6 orders of magnitude.
        let vals: Vec<f64> = (0..500)
            .map(|_| (rng.f64() * 6.0 - 3.0).exp2() * (1.0 + rng.f64()))
            .collect();
        let s = sketch_of(&vals);
        let mut sorted = vals.clone();
        sorted.sort_by(f64::total_cmp);
        for q in [0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0] {
            let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
            let exact = sorted[rank - 1];
            let est = s.quantile(q);
            // Bound: midpoint of a bucket of width 2^e/SUB around a
            // value >= 2^e, i.e. 1/(2*SUB) = 6.25% relative.
            assert!(
                (est - exact).abs() <= exact * (1.0 / (2.0 * SUB as f64)) + 1e-12,
                "q={q}: est {est} exact {exact}"
            );
        }
    }

    #[test]
    fn min_and_max_stay_exact_through_merges() {
        let a = sketch_of(&[4.0, 9.0, 1.5]);
        let mut b = sketch_of(&[8.25, 0.5]);
        b.merge(&a);
        assert_eq!(b.min(), 0.5);
        assert_eq!(b.max(), 9.0);
        assert_eq!(b.count(), 5);
        assert_eq!(b.quantile(0.0), 0.5);
        assert_eq!(b.quantile(1.0), 9.0);
    }
}
