//! Small self-contained utilities: deterministic PRNG, statistics,
//! a JSON writer, a micro-benchmark harness and a property-test driver.
//!
//! The offline vendor set has no `rand`/`serde`/`criterion`/`proptest`, so
//! these are in-repo implementations sized to what the framework needs.

pub mod bench;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod sketch;
pub mod stats;

pub use rng::Rng;
