//! Minimal JSON writer for experiment outputs (no serde in the offline
//! vendor set). Only what the eval harness needs: objects, arrays,
//! numbers, strings, bools.

use std::fmt::Write as _;

/// A JSON value tree.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object from key/value pairs (ordered).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Array of numbers.
    pub fn nums<I: IntoIterator<Item = f64>>(it: I) -> Json {
        Json::Arr(it.into_iter().map(Json::Num).collect())
    }

    /// Serialize to a compact string.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_numbers_are_integers() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }

    #[test]
    fn object_serialization() {
        let j = Json::obj(vec![
            ("name", "fig9".into()),
            ("acc", Json::nums([0.5, 0.75])),
            ("ok", Json::Bool(true)),
        ]);
        assert_eq!(j.to_string(), r#"{"name":"fig9","acc":[0.5,0.75],"ok":true}"#);
    }

    #[test]
    fn string_escaping() {
        assert_eq!(Json::Str("a\"b\n".into()).to_string(), "\"a\\\"b\\n\"");
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }
}
