//! Minimal JSON reader/writer for experiment specs and outputs (no serde
//! in the offline vendor set). The writer covers what the eval harness
//! emits; the reader is a strict recursive-descent parser sized for the
//! scenario/sweep spec files (`ilearn run --spec`, `ilearn sweep`).

use crate::error::{Error, Result};
use std::fmt::Write as _;

/// A JSON value tree.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object from key/value pairs (ordered).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Array of numbers.
    pub fn nums<I: IntoIterator<Item = f64>>(it: I) -> Json {
        Json::Arr(it.into_iter().map(Json::Num).collect())
    }

    /// Parse a JSON document (strict: one value, no trailing garbage;
    /// nesting capped so malformed input errors instead of blowing the
    /// stack).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
            depth: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    /// Object field lookup (`None` on non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Non-negative integer view of a number (rejects fractions).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 2f64.powi(63) => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs.as_slice()),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}

// ------------------------------------------------------------------ parser

/// Recursion guard: far deeper than any spec file, far shallower than
/// the stack.
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Config(format!("json parse error at byte {}: {msg}", self.i))
    }

    fn descend(&mut self) -> Result<()> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err(&format!("nesting deeper than {MAX_DEPTH} levels")));
        }
        Ok(())
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected `{}`", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{lit}`")))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).expect("ascii");
        match s.parse::<f64>() {
            // overflow parses to ±inf, which the writer can't represent
            // (it maps non-finite to null) — reject instead of letting a
            // typo'd exponent slip through as infinity
            Ok(n) if n.is_finite() => Ok(Json::Num(n)),
            Ok(_) => Err(self.err(&format!("number `{s}` out of f64 range"))),
            Err(_) => Err(self.err(&format!("bad number `{s}`"))),
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("non-ascii \\u escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.i += 4;
        Ok(v)
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("truncated escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair: require \uXXXX low half
                                if self.peek() == Some(b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        return Err(self.err("bad low surrogate"));
                                    }
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("invalid codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // copy the full UTF-8 sequence starting at c
                    let len = match c {
                        0x00..=0x7F => 0,
                        0xC0..=0xDF => 1,
                        0xE0..=0xEF => 2,
                        _ => 3,
                    };
                    if len == 0 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        if start + 1 + len > self.b.len() {
                            return Err(self.err("truncated UTF-8 sequence"));
                        }
                        self.i += len;
                        let s = std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid UTF-8 in string"))?;
                        out.push_str(s);
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.descend()?;
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.ws();
            xs.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.descend()?;
        self.expect(b'{')?;
        let mut kvs = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Obj(kvs));
        }
        loop {
            self.ws();
            let k = self.string()?;
            // `get` is first-match; accepting duplicates would silently
            // shadow the value most tools (last-wins) would show the user
            if kvs.iter().any(|(existing, _)| *existing == k) {
                return Err(self.err(&format!("duplicate object key `{k}`")));
            }
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            kvs.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(kvs));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_numbers_are_integers() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }

    #[test]
    fn object_serialization() {
        let j = Json::obj(vec![
            ("name", "fig9".into()),
            ("acc", Json::nums([0.5, 0.75])),
            ("ok", Json::Bool(true)),
        ]);
        assert_eq!(j.to_string(), r#"{"name":"fig9","acc":[0.5,0.75],"ok":true}"#);
    }

    #[test]
    fn string_escaping() {
        assert_eq!(Json::Str("a\"b\n".into()).to_string(), "\"a\\\"b\\n\"");
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn parse_round_trips_writer_output() {
        let j = Json::obj(vec![
            ("name", "vibration".into()),
            ("seed", Json::Num(42.0)),
            ("xs", Json::nums([1.0, 2.5, -3.0])),
            ("deep", Json::obj(vec![("null", Json::Null), ("b", Json::Bool(false))])),
            ("text", Json::Str("line\n\"quoted\" \\ tab\t".into())),
        ]);
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn parse_handles_whitespace_and_scientific_notation() {
        let j = Json::parse(" { \"a\" : [ 1e3 , 2.5E-2, -4 ] }\n").unwrap();
        let xs = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(xs[0].as_f64(), Some(1000.0));
        assert_eq!(xs[1].as_f64(), Some(0.025));
        assert_eq!(xs[2].as_f64(), Some(-4.0));
    }

    #[test]
    fn parse_unicode_escapes() {
        let j = Json::parse(r#""aé😀b""#).unwrap();
        assert_eq!(j.as_str(), Some("aé😀b"));
        let e = Json::parse("\"\\u00e9 \\ud83d\\ude00\"").unwrap();
        assert_eq!(e.as_str(), Some("é 😀"));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
        assert!(Json::parse("{'a':1}").is_err());
        assert!(Json::parse("nul").is_err());
        // overflowing exponents must not become infinity
        assert!(Json::parse("1e999").is_err());
        assert!(Json::parse("[-1e999]").is_err());
        // duplicate keys would silently shadow a value
        assert!(Json::parse(r#"{"seed":1,"seed":7}"#).is_err());
    }

    #[test]
    fn deep_nesting_errors_instead_of_overflowing() {
        let bomb = "[".repeat(100_000);
        let err = Json::parse(&bomb).unwrap_err();
        assert!(err.to_string().contains("nesting"), "{err}");
        // a legal, moderately nested document still parses
        let ok = format!("{}1{}", "[".repeat(64), "]".repeat(64));
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn accessors() {
        let j = Json::parse(r#"{"n":7,"s":"x","b":true,"z":null}"#).unwrap();
        assert_eq!(j.get("n").unwrap().as_u64(), Some(7));
        assert_eq!(j.get("s").unwrap().as_str(), Some("x"));
        assert_eq!(j.get("b").unwrap().as_bool(), Some(true));
        assert!(j.get("z").unwrap().is_null());
        assert!(j.get("missing").is_none());
        assert_eq!(Json::Num(1.5).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
    }
}
