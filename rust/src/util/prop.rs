//! Tiny property-testing driver (proptest is not in the offline vendor
//! set). A property is a closure over a seeded [`Rng`]; the driver runs it
//! for `cases` independent seeds and reports the first failing seed so a
//! failure is reproducible with `check_seeded`.
//!
//! No shrinking — generators here are expected to produce small inputs
//! already (the coordinator-invariant tests generate scenario parameters,
//! not deep structures).

use super::rng::Rng;

/// Number of cases used by default across the test suite.
pub const DEFAULT_CASES: u64 = 64;

/// Run `prop` for `cases` seeds derived from `base_seed`. Panics with the
/// failing seed embedded in the message.
pub fn check_cases<F: Fn(&mut Rng)>(name: &str, base_seed: u64, cases: u64, prop: F) {
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9e3779b97f4a7c15)
            .wrapping_add(case);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property `{name}` failed at seed {seed} (case {case}/{cases}): {msg}");
        }
    }
}

/// Run `prop` with the default number of cases.
pub fn check<F: Fn(&mut Rng)>(name: &str, prop: F) {
    check_cases(name, 0xC0FFEE, DEFAULT_CASES, prop);
}

/// Re-run a single failing seed (paste from the panic message).
pub fn check_seeded<F: Fn(&mut Rng)>(seed: u64, prop: F) {
    let mut rng = Rng::new(seed);
    prop(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", |rng| {
            let a = rng.below(1000) as u64;
            let b = rng.below(1000) as u64;
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed at seed")]
    fn failing_property_reports_seed() {
        check_cases("always-fails", 1, 4, |_| panic!("boom"));
    }
}
