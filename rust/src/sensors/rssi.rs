//! RSSI sensor for the RF human-presence learner (§6.2).
//!
//! Presence detection works on *short-term variation* of RSSI: a person
//! moving near the antenna perturbs the multipath pattern, raising the
//! variance (and shifting the mean) of consecutive RSSI readings. Each
//! *area* (the paper moves the system between three areas) has its own
//! base RSSI level and noise floor, so a model learned in one area
//! mispredicts in the next until it re-learns — reproducing Fig. 7(c).

use super::{Episodes, Sensor, Window};

/// Per-area RF characteristics.
#[derive(Debug, Clone, Copy)]
pub struct Area {
    /// When the system is moved into this area.
    pub start_us: u64,
    /// Base RSSI in dBm at the deployment spot.
    pub base_dbm: f64,
    /// Ambient (no-human) noise std, dB.
    pub noise_db: f64,
    /// Extra std added while a human is present, dB.
    pub human_db: f64,
    /// Mean shift while a human is present (body shadowing), dB.
    pub human_shift_db: f64,
}

/// Synthetic RSSI world with presence episodes and area moves.
#[derive(Debug, Clone)]
pub struct Rssi {
    pub areas: Vec<Area>,
    pub presence: Episodes,
    pub seed: u64,
}

impl Rssi {
    /// The paper's 3-area deployment: distinct base levels / noise, with
    /// presence episodes (someone walking by) every few minutes.
    pub fn three_areas(seed: u64, horizon_us: u64, area_len_us: u64) -> Self {
        let areas = vec![
            Area {
                start_us: 0,
                base_dbm: -52.0,
                noise_db: 0.8,
                human_db: 3.0,
                human_shift_db: -4.0,
            },
            Area {
                start_us: area_len_us,
                base_dbm: -63.0,
                noise_db: 1.6,
                human_db: 2.2,
                human_shift_db: 2.5,
            },
            Area {
                start_us: 2 * area_len_us,
                base_dbm: -58.0,
                noise_db: 1.1,
                human_db: 4.0,
                human_shift_db: -3.0,
            },
        ];
        Rssi {
            areas,
            presence: Episodes::poisson(
                seed,
                horizon_us,
                240_000_000,  // someone passes every ~4 min
                20_000_000,   // stays 20 s ..
                90_000_000,   // .. to 90 s
            ),
            seed,
        }
    }

    /// The active area at `t_us`.
    pub fn area_at(&self, t_us: u64) -> &Area {
        let mut cur = &self.areas[0];
        for a in &self.areas {
            if t_us >= a.start_us {
                cur = a;
            } else {
                break;
            }
        }
        cur
    }

    fn hash01(&self, bucket: u64, salt: u64) -> f64 {
        let mut z = self.seed ^ bucket.wrapping_mul(0x9E3779B97F4A7C15) ^ (salt << 40);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^= z >> 31;
        (z >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Approximate standard normal from 4 hashed uniforms (CLT).
    fn gauss(&self, bucket: u64, salt: u64) -> f64 {
        let s: f64 = (0..4).map(|i| self.hash01(bucket, salt * 4 + i)).sum();
        (s - 2.0) * (12.0f64 / 4.0).sqrt()
    }

    /// One RSSI reading (dBm) at time `t_us`.
    pub fn reading_dbm(&self, t_us: u64) -> f64 {
        let a = self.area_at(t_us);
        let present = self.presence.contains(t_us);
        let idx = t_us / 10_000; // 10 ms buckets: consecutive reads decorrelate
        let mut v = a.base_dbm + a.noise_db * self.gauss(idx, 1);
        if present {
            v += a.human_shift_db + a.human_db * self.gauss(idx, 2);
        }
        v
    }
}

impl Sensor for Rssi {
    fn channels(&self) -> usize {
        1
    }

    fn window(&self, t_us: u64, w: usize) -> Window {
        let dt = self.sample_period_us();
        let mut data = vec![0.0f32; w];
        let mut abnormal = false;
        for r in 0..w {
            let t = t_us + r as u64 * dt;
            // normalize dBm into a small range for the learner
            data[r] = ((self.reading_dbm(t) + 60.0) / 10.0) as f32;
            abnormal |= self.presence.contains(t);
        }
        Window {
            t_us,
            data,
            w,
            c: 1,
            truth_abnormal: abnormal,
        }
    }

    fn truth_at(&self, t_us: u64) -> bool {
        self.presence.contains(t_us)
    }

    /// §6.2: 10–30 RSSI readings per example at tens of ms cadence.
    fn sample_period_us(&self) -> u64 {
        30_000
    }

    fn name(&self) -> &'static str {
        "rssi"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const H: u64 = 3_600_000_000;

    #[test]
    fn area_schedule_lookup() {
        let r = Rssi::three_areas(1, 9 * H, 3 * H);
        assert_eq!(r.area_at(0).base_dbm, -52.0);
        assert_eq!(r.area_at(3 * H + 1).base_dbm, -63.0);
        assert_eq!(r.area_at(8 * H).base_dbm, -58.0);
    }

    #[test]
    fn presence_raises_short_term_variance() {
        let mut r = Rssi::three_areas(2, 9 * H, 3 * H);
        r.presence = Episodes(vec![(H, H + 600_000_000)]);
        let var = |t0: u64| {
            let w = r.window(t0, 30);
            crate::util::stats::std(&w.data)
        };
        // average over several windows to beat noise
        let quiet: f32 = (0..8).map(|i| var(2 * H + i * 2_000_000)).sum::<f32>() / 8.0;
        let busy: f32 = (0..8).map(|i| var(H + i * 2_000_000)).sum::<f32>() / 8.0;
        assert!(busy > 1.5 * quiet, "busy {busy} quiet {quiet}");
    }

    #[test]
    fn different_areas_have_different_levels() {
        let r = Rssi::three_areas(3, 9 * H, 3 * H);
        let m = |t0: u64| {
            let w = r.window(t0, 30);
            crate::util::stats::mean(&w.data)
        };
        assert!((m(H) - m(4 * H)).abs() > 0.5);
    }

    #[test]
    fn deterministic() {
        let r = Rssi::three_areas(4, 9 * H, 3 * H);
        assert_eq!(r.window(H, 20).data, r.window(H, 20).data);
    }
}
