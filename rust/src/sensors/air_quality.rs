//! Air-quality sensor trio (UV, eCO2, TVOC) with injected anomalies.
//!
//! §6.1: the solar-powered learner reads UV, eCO2 and TVOC every 32 s and
//! detects anomalies against the learned normal profile. The synthetic
//! world: UV follows the diurnal irradiance curve; eCO2 and TVOC drift
//! slowly around indoor baselines with small noise. Anomaly episodes
//! (e.g. a ventilation failure or a VOC release) push one or more
//! channels far outside the learned envelope for tens of minutes.

use super::{Episodes, Sensor, Window};

const DAY_US: u64 = 86_400_000_000;

/// Synthetic UV/eCO2/TVOC world.
#[derive(Debug, Clone)]
pub struct AirQuality {
    pub episodes: Episodes,
    pub seed: u64,
    /// eCO2 baseline ppm.
    pub co2_base: f64,
    /// TVOC baseline ppb.
    pub tvoc_base: f64,
}

impl AirQuality {
    /// Default world over a horizon: anomaly episodes mean every ~5 h,
    /// lasting 15–45 min.
    pub fn new(seed: u64, horizon_us: u64) -> Self {
        AirQuality {
            episodes: Episodes::poisson(
                seed,
                horizon_us,
                5 * 3_600_000_000,
                15 * 60_000_000,
                45 * 60_000_000,
            ),
            seed,
            co2_base: 520.0,
            tvoc_base: 110.0,
        }
    }

    fn hash01(&self, bucket: u64, salt: u64) -> f64 {
        let mut z = self.seed ^ bucket.wrapping_mul(0x9E3779B97F4A7C15) ^ (salt << 48);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^= z >> 31;
        (z >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Channel values at an instant: (uv index, eCO2 ppm, TVOC ppb),
    /// normalized into comparable ranges for the learner.
    fn values(&self, t_us: u64) -> [f32; 3] {
        let tod = (t_us % DAY_US) as f64 / 1e6; // seconds of day
        let sunrise = 6.0 * 3600.0;
        let sunset = 19.0 * 3600.0;
        let uv_clear = if tod > sunrise && tod < sunset {
            let phase = (tod - sunrise) / (sunset - sunrise);
            8.0 * (std::f64::consts::PI * phase).sin().max(0.0)
        } else {
            0.0
        };
        let minute = t_us / 60_000_000;
        let uv = uv_clear * (0.85 + 0.15 * self.hash01(minute, 1));

        // slow random-walk drift (hour bucket) + per-minute noise
        let hour = t_us / 3_600_000_000;
        let drift_c = 60.0 * (self.hash01(hour, 2) - 0.5);
        let drift_t = 30.0 * (self.hash01(hour, 3) - 0.5);
        let mut co2 = self.co2_base + drift_c + 20.0 * (self.hash01(minute, 4) - 0.5);
        let mut tvoc = self.tvoc_base + drift_t + 12.0 * (self.hash01(minute, 5) - 0.5);
        let mut uv_out = uv;

        if self.episodes.contains(t_us) {
            // Anomaly: CO2 surge + VOC release + (daytime) haze knocks UV.
            let sev = 1.0 + 2.0 * self.hash01(t_us / 300_000_000, 6);
            co2 += 600.0 * sev;
            tvoc += 350.0 * sev;
            uv_out *= 0.35;
        }

        // Normalize to comparable scales (z-score-ish ranges) so the
        // Euclidean feature distance is not dominated by ppm units.
        [
            (uv_out / 8.0) as f32,
            ((co2 - self.co2_base) / 200.0) as f32,
            ((tvoc - self.tvoc_base) / 100.0) as f32,
        ]
    }
}

impl Sensor for AirQuality {
    fn channels(&self) -> usize {
        3
    }

    fn window(&self, t_us: u64, w: usize) -> Window {
        let dt = self.sample_period_us();
        let mut data = vec![0.0f32; w * 3];
        let mut abnormal = false;
        for r in 0..w {
            let t = t_us + r as u64 * dt;
            let v = self.values(t);
            data[r * 3] = v[0];
            data[r * 3 + 1] = v[1];
            data[r * 3 + 2] = v[2];
            abnormal |= self.episodes.contains(t);
        }
        Window {
            t_us,
            data,
            w,
            c: 3,
            truth_abnormal: abnormal,
        }
    }

    fn truth_at(&self, t_us: u64) -> bool {
        self.episodes.contains(t_us)
    }

    /// Paper: one reading every 32 s; we compress to 2 s of simulated time
    /// per sample so multi-week behaviour fits in tractable horizons while
    /// keeping the diurnal structure (documented in DESIGN.md §1).
    fn sample_period_us(&self) -> u64 {
        2_000_000
    }

    fn name(&self) -> &'static str {
        "air_quality"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const H: u64 = 3_600_000_000;

    #[test]
    fn uv_is_diurnal() {
        let aq = AirQuality::new(1, 48 * H);
        let noon = aq.values(12 * H)[0];
        let midnight = aq.values(0)[0];
        assert!(noon > 0.3);
        assert_eq!(midnight, 0.0);
    }

    #[test]
    fn anomaly_shifts_co2_and_tvoc() {
        let mut aq = AirQuality::new(2, 48 * H);
        aq.episodes = Episodes(vec![(10 * H, 11 * H)]);
        let norm = aq.values(9 * H);
        let anom = aq.values(10 * H + H / 2);
        assert!(anom[1] > norm[1] + 2.0);
        assert!(anom[2] > norm[2] + 2.0);
        assert!(aq.truth_at(10 * H + 1));
        assert!(!aq.truth_at(9 * H));
    }

    #[test]
    fn window_truth_reflects_overlap() {
        let mut aq = AirQuality::new(3, 48 * H);
        aq.episodes = Episodes(vec![(H, 2 * H)]);
        let w_in = aq.window(H + 1000, 32);
        let w_out = aq.window(4 * H, 32);
        assert!(w_in.truth_abnormal);
        assert!(!w_out.truth_abnormal);
    }

    #[test]
    fn deterministic() {
        let aq = AirQuality::new(4, 48 * H);
        assert_eq!(aq.window(7 * H, 60).data, aq.window(7 * H, 60).data);
    }

    #[test]
    fn default_world_has_episodes() {
        let aq = AirQuality::new(5, 7 * 24 * H);
        assert!(aq.episodes.0.len() >= 10, "{}", aq.episodes.0.len());
    }
}
