//! Synthetic sensor-world generators with ground truth.
//!
//! Substitution (DESIGN.md §1): the paper's deployments sense real
//! UV/eCO2/TVOC, RSSI and 3-axis acceleration, with anomalies labelled by
//! human experts after the fact. Here each sensor is a deterministic
//! generator seeded per experiment, with anomaly episodes injected on a
//! known schedule — so accuracy can be *computed* against exact ground
//! truth while the learner sees the same windowed statistics it would on
//! the physical platform.
//!
//! A sensor is sampled at `sense` time by the intermittent engine; the
//! returned [`Window`] carries the ground-truth label for later scoring
//! (the label is never visible to the unsupervised learner; the
//! semi-supervised vibration learner receives a few labelled windows at
//! bootstrap, as in §6.3's cluster-then-label scheme).

pub mod accel;
pub mod air_quality;
pub mod rssi;

pub use accel::{Accel, MotionProfile};
pub use air_quality::AirQuality;
pub use rssi::Rssi;

/// One sensing window: `w` samples × `c` channels, row-major.
#[derive(Debug, Clone)]
pub struct Window {
    /// Simulated acquisition time (start of window), µs.
    pub t_us: u64,
    /// Row-major (w, c) samples.
    pub data: Vec<f32>,
    pub w: usize,
    pub c: usize,
    /// Ground truth: is the phenomenon abnormal during this window?
    pub truth_abnormal: bool,
}

impl Window {
    /// Sample at (row, channel).
    #[inline]
    pub fn at(&self, row: usize, ch: usize) -> f32 {
        self.data[row * self.c + ch]
    }

    /// One channel as a contiguous vector.
    pub fn channel(&self, ch: usize) -> Vec<f32> {
        (0..self.w).map(|r| self.at(r, ch)).collect()
    }

    /// Pad/truncate to (w_out, c_out) — used to fit the fixed AOT artifact
    /// shapes (missing channels zero-filled, missing rows repeat the last
    /// sample so window statistics are minimally perturbed).
    pub fn fit(&self, w_out: usize, c_out: usize) -> Window {
        let mut data = vec![0.0f32; w_out * c_out];
        for r in 0..w_out {
            let src_r = r.min(self.w.saturating_sub(1));
            for ch in 0..c_out.min(self.c) {
                data[r * c_out + ch] = if self.w == 0 { 0.0 } else { self.at(src_r, ch) };
            }
        }
        Window {
            t_us: self.t_us,
            data,
            w: w_out,
            c: c_out,
            truth_abnormal: self.truth_abnormal,
        }
    }
}

/// A deterministic, seekable sensor stream.
pub trait Sensor: Send {
    /// Number of physical channels.
    fn channels(&self) -> usize;

    /// Acquire a window of `w` samples starting at `t_us`.
    fn window(&self, t_us: u64, w: usize) -> Window;

    /// Ground truth at an instant (for evaluation probes).
    fn truth_at(&self, t_us: u64) -> bool;

    /// Native inter-sample period, µs.
    fn sample_period_us(&self) -> u64;

    fn name(&self) -> &'static str;
}

/// Episode list helper: half-open [start, end) intervals in µs, sorted.
#[derive(Debug, Clone, Default)]
pub struct Episodes(pub Vec<(u64, u64)>);

impl Episodes {
    /// Is `t` inside any episode?
    pub fn contains(&self, t: u64) -> bool {
        // episodes are sorted by start; binary search for the candidate
        match self.0.binary_search_by(|&(s, _)| s.cmp(&t)) {
            Ok(_) => true,
            Err(0) => false,
            Err(i) => t < self.0[i - 1].1,
        }
    }

    /// Does [t0, t1) overlap any episode?
    pub fn overlaps(&self, t0: u64, t1: u64) -> bool {
        self.0.iter().any(|&(s, e)| s < t1 && t0 < e)
    }

    /// Generate episodes with mean inter-arrival `gap_us` and duration in
    /// [dur_lo, dur_hi], deterministically from `seed`, covering [0, horizon).
    pub fn poisson(seed: u64, horizon_us: u64, gap_us: u64, dur_lo: u64, dur_hi: u64) -> Self {
        let mut rng = crate::util::Rng::with_stream(seed, 0xE1150DE5);
        let mut eps = Vec::new();
        let mut t = (gap_us as f64 * (0.3 + rng.f64())) as u64;
        while t < horizon_us {
            let dur = dur_lo + (rng.f64() * (dur_hi - dur_lo) as f64) as u64;
            eps.push((t, (t + dur).min(horizon_us)));
            // exponential-ish gap: -ln(U) * mean
            let gap = (-(rng.f64().max(1e-12)).ln() * gap_us as f64) as u64;
            t = t + dur + gap.max(gap_us / 10);
        }
        Episodes(eps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_indexing_and_channel() {
        let w = Window {
            t_us: 0,
            data: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
            w: 3,
            c: 2,
            truth_abnormal: false,
        };
        assert_eq!(w.at(0, 1), 2.0);
        assert_eq!(w.at(2, 0), 5.0);
        assert_eq!(w.channel(1), vec![2.0, 4.0, 6.0]);
    }

    #[test]
    fn fit_pads_rows_and_channels() {
        let w = Window {
            t_us: 9,
            data: vec![1.0, 2.0, 3.0, 4.0],
            w: 2,
            c: 2,
            truth_abnormal: true,
        };
        let f = w.fit(4, 3);
        assert_eq!((f.w, f.c), (4, 3));
        assert_eq!(f.at(0, 0), 1.0);
        assert_eq!(f.at(3, 1), 4.0); // repeated last row
        assert_eq!(f.at(1, 2), 0.0); // zero-filled channel
        assert!(f.truth_abnormal);
    }

    #[test]
    fn episodes_contains_and_overlaps() {
        let e = Episodes(vec![(10, 20), (50, 60)]);
        assert!(!e.contains(9));
        assert!(e.contains(10));
        assert!(e.contains(19));
        assert!(!e.contains(20));
        assert!(e.overlaps(15, 55));
        assert!(!e.overlaps(20, 50));
    }

    #[test]
    fn poisson_episodes_deterministic_and_bounded() {
        let h = 3_600_000_000; // 1 h
        let a = Episodes::poisson(7, h, 300_000_000, 10_000_000, 60_000_000);
        let b = Episodes::poisson(7, h, 300_000_000, 10_000_000, 60_000_000);
        assert_eq!(a.0, b.0);
        assert!(!a.0.is_empty());
        for &(s, e) in &a.0 {
            assert!(s < e && e <= h);
        }
        // sorted & non-overlapping
        for w in a.0.windows(2) {
            assert!(w[0].1 <= w[1].0);
        }
    }
}
