//! Accelerometer sensor + the motion profile that drives BOTH the sensor
//! and the piezoelectric harvester (the paper's §2.3 energy↔data
//! correlation: arm shaking generates the vibration data *and* the energy
//! to learn it).
//!
//! §6.3's controlled experiment: gentle shaking (<5 shakes / 5 s) vs
//! abrupt shaking (>10 shakes / 5 s), alternating one-hour segments,
//! sampled by a LIS3DH at 50 Hz. Gentle = normal, abrupt = abnormal.

use super::{Sensor, Window};

/// A motion episode: sinusoidal shaking with given amplitude & frequency.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MotionEpisode {
    pub start_us: u64,
    pub end_us: u64,
    /// Peak acceleration amplitude, g.
    pub amp: f64,
    /// Shake frequency, Hz.
    pub freq_hz: f64,
    /// Ground-truth label: is this abrupt (abnormal) motion?
    pub abnormal: bool,
}

/// Piecewise motion schedule shared by [`Accel`] and
/// [`crate::energy::harvester::Piezo`].
#[derive(Debug, Clone, Default)]
pub struct MotionProfile {
    pub episodes: Vec<MotionEpisode>,
}

impl MotionProfile {
    /// The paper's §6.3/§7.4 protocol: alternating one-hour segments of
    /// gentle and abrupt shaking, *100 discrete gestures per hour* (the
    /// paper performs 100 shaking gestures in each hour), each ~5 s long.
    /// Between gestures there is no motion — and therefore neither data
    /// nor harvested energy (the §2.3 correlation).
    pub fn alternating_hours(gentle: f64, abrupt: f64, hours: u64) -> Self {
        Self::gesture_hours(gentle, abrupt, hours, 100)
    }

    /// Like [`Self::alternating_hours`] with an explicit gesture count.
    pub fn gesture_hours(gentle: f64, abrupt: f64, hours: u64, per_hour: u64) -> Self {
        const H: u64 = 3_600_000_000;
        const GESTURE_US: u64 = 5_000_000;
        let spacing = H / per_hour.max(1);
        let mut episodes = Vec::with_capacity((hours * per_hour) as usize);
        for h in 0..hours {
            let is_abrupt = h % 2 == 1;
            for g in 0..per_hour {
                // deterministic jitter so gestures don't alias with the
                // engine's checkpoint cadence
                let jitter = (h.wrapping_mul(31) ^ g.wrapping_mul(7)) % (spacing / 4);
                let start = h * H + g * spacing + jitter;
                episodes.push(MotionEpisode {
                    start_us: start,
                    end_us: (start + GESTURE_US).min((h + 1) * H),
                    amp: if is_abrupt { abrupt } else { gentle },
                    // gentle: <5 shakes per 5 s (≈0.9 Hz); abrupt: >10 per 5 s (≈2.6 Hz)
                    freq_hz: if is_abrupt { 2.6 } else { 0.9 },
                    abnormal: is_abrupt,
                });
            }
        }
        MotionProfile { episodes }
    }

    /// The active episode at `t_us`, if any (binary search; episodes are
    /// sorted and non-overlapping).
    pub fn episode_at(&self, t_us: u64) -> Option<&MotionEpisode> {
        let idx = match self
            .episodes
            .binary_search_by(|e| e.start_us.cmp(&t_us))
        {
            Ok(i) => i,
            Err(0) => return None,
            Err(i) => i - 1,
        };
        let e = &self.episodes[idx];
        (t_us < e.end_us).then_some(e)
    }

    /// Start time of the n-th gesture (testing helper).
    pub fn gesture_start(&self, n: usize) -> u64 {
        self.episodes[n].start_us
    }

    /// End (exclusive) of the piecewise-constant motion segment containing
    /// `t_us`: the active episode's end while shaking, otherwise the next
    /// episode's start (`u64::MAX` once the protocol is over).
    pub fn segment_end_us(&self, t_us: u64) -> u64 {
        if let Some(e) = self.episode_at(t_us) {
            return e.end_us;
        }
        let idx = self
            .episodes
            .partition_point(|e| e.start_us <= t_us);
        self.episodes
            .get(idx)
            .map(|e| e.start_us)
            .unwrap_or(u64::MAX)
    }

    /// Instantaneous motion amplitude (g); 0 when idle.
    pub fn amplitude(&self, t_us: u64) -> f64 {
        self.episode_at(t_us).map(|e| e.amp).unwrap_or(0.0)
    }
}

/// Simulated 3-axis accelerometer.
#[derive(Debug, Clone)]
pub struct Accel {
    pub profile: MotionProfile,
    /// Sampling rate (paper: 50 Hz).
    pub rate_hz: f64,
    /// Sensor noise std, g.
    pub noise_g: f64,
    pub seed: u64,
}

impl Accel {
    pub fn new(profile: MotionProfile, seed: u64) -> Self {
        Accel {
            profile,
            rate_hz: 50.0,
            noise_g: 0.03,
            seed,
        }
    }

    /// Deterministic per-sample noise (hash of sample index).
    fn noise(&self, idx: u64, axis: u64) -> f32 {
        let mut z = self.seed ^ idx.wrapping_mul(0x9E3779B97F4A7C15) ^ (axis << 56);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^= z >> 31;
        let u = (z >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        ((u - 0.5) * 2.0 * self.noise_g * 1.7320508) as f32 // uniform, same std
    }
}

impl Sensor for Accel {
    fn channels(&self) -> usize {
        3
    }

    fn window(&self, t_us: u64, w: usize) -> Window {
        let dt_us = self.sample_period_us();
        let mut data = vec![0.0f32; w * 3];
        let mut any_abnormal = false;
        for r in 0..w {
            let t = t_us + r as u64 * dt_us;
            let t_s = t as f64 / 1e6;
            let (amp, freq, abn) = self
                .profile
                .episode_at(t)
                .map(|e| (e.amp, e.freq_hz, e.abnormal))
                .unwrap_or((0.0, 0.0, false));
            any_abnormal |= abn;
            let phase = 2.0 * std::f64::consts::PI * freq * t_s;
            let idx = t / dt_us.max(1);
            // x: main shake axis; y: half-amplitude, quarter-phase lag;
            // z: gravity plus small coupling.
            data[r * 3] = (amp * phase.sin()) as f32 + self.noise(idx, 0);
            data[r * 3 + 1] =
                (0.5 * amp * (phase - 0.7).sin()) as f32 + self.noise(idx, 1);
            data[r * 3 + 2] =
                1.0 + (0.2 * amp * (2.0 * phase).sin()) as f32 + self.noise(idx, 2);
        }
        Window {
            t_us,
            data,
            w,
            c: 3,
            truth_abnormal: any_abnormal,
        }
    }

    fn truth_at(&self, t_us: u64) -> bool {
        self.profile
            .episode_at(t_us)
            .map(|e| e.abnormal)
            .unwrap_or(false)
    }

    fn sample_period_us(&self) -> u64 {
        (1e6 / self.rate_hz) as u64
    }

    fn name(&self) -> &'static str {
        "accel"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alternating_profile_labels() {
        let p = MotionProfile::alternating_hours(1.0, 3.0, 4);
        assert_eq!(p.episodes.len(), 400); // 100 gestures x 4 hours
        const H: u64 = 3_600_000_000;
        // gestures in even hours are gentle, odd hours abrupt
        assert!(!p.episodes[0].abnormal);
        assert!(p.episodes[150].abnormal);
        assert_eq!(p.amplitude(p.gesture_start(0) + 1), 1.0);
        assert_eq!(p.amplitude(p.gesture_start(150) + 1), 3.0);
        assert_eq!(p.amplitude(4 * H + 1), 0.0); // after the experiment
        // between gestures: idle
        assert_eq!(p.amplitude(p.episodes[0].end_us + 1_000), 0.0);
    }

    #[test]
    fn episode_binary_search_agrees_with_scan() {
        let p = MotionProfile::alternating_hours(1.0, 3.0, 2);
        for t in (0..7_200_000_000u64).step_by(13_777_777) {
            let scan = p
                .episodes
                .iter()
                .find(|e| e.start_us <= t && t < e.end_us)
                .map(|e| e.start_us);
            let fast = p.episode_at(t).map(|e| e.start_us);
            assert_eq!(scan, fast, "t={t}");
        }
    }

    #[test]
    fn segment_end_tracks_episode_boundaries() {
        let p = MotionProfile::alternating_hours(1.0, 3.0, 2);
        // inside a gesture: the segment ends with the gesture
        let g0 = p.episodes[0];
        assert_eq!(p.segment_end_us(g0.start_us), g0.end_us);
        assert_eq!(p.segment_end_us(g0.start_us + 1_000), g0.end_us);
        // idle gap: the segment ends at the next gesture's start
        assert_eq!(p.segment_end_us(g0.end_us), p.episodes[1].start_us);
        // past the protocol: one segment forever
        let last = p.episodes.last().unwrap();
        assert_eq!(p.segment_end_us(last.end_us + 1), u64::MAX);
        // before the first gesture
        if g0.start_us > 0 {
            assert_eq!(p.segment_end_us(0), g0.start_us);
        }
    }

    #[test]
    fn windows_are_deterministic() {
        let a = Accel::new(MotionProfile::alternating_hours(1.0, 3.0, 2), 5);
        let w1 = a.window(1_000_000, 64);
        let w2 = a.window(1_000_000, 64);
        assert_eq!(w1.data, w2.data);
    }

    #[test]
    fn abrupt_windows_have_higher_energy() {
        let a = Accel::new(MotionProfile::alternating_hours(1.0, 3.0, 2), 5);
        // sample inside actual gestures (hour 0 = gentle, hour 1 = abrupt)
        let gentle = a.window(a.profile.gesture_start(50), 128);
        let abrupt = a.window(a.profile.gesture_start(150), 128);
        let rms = |w: &Window| crate::util::stats::rms(&w.channel(0));
        assert!(rms(&abrupt) > 2.0 * rms(&gentle));
        assert!(!gentle.truth_abnormal);
        assert!(abrupt.truth_abnormal);
    }

    #[test]
    fn z_axis_carries_gravity() {
        let a = Accel::new(MotionProfile::default(), 5);
        let w = a.window(0, 64);
        let mean_z = crate::util::stats::mean(&w.channel(2));
        assert!((mean_z - 1.0).abs() < 0.1, "mean_z {mean_z}");
    }

    #[test]
    fn sample_period_matches_rate() {
        let a = Accel::new(MotionProfile::default(), 1);
        assert_eq!(a.sample_period_us(), 20_000); // 50 Hz
    }
}
