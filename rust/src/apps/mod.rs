//! The three intermittent-learning applications of §6 as *thin preset
//! factories* over the scenario API: air-quality (solar, k-NN), human
//! presence (RF, k-NN over RSSI), vibration (piezo, NN-k-means
//! cluster-then-label).
//!
//! All world-construction knowledge lives in [`crate::scenario`] presets;
//! this module only names the apps and carries the legacy [`AppConfig`]
//! convenience struct, whose `build_engine` is a one-liner over
//! [`ScenarioSpec::build_engine`]. New code should use
//! [`crate::scenario::preset`] / [`ScenarioSpec`] directly — that is the
//! (app × scheduler × heuristic × backend) matrix §7 sweeps, and more.

use crate::energy::CostModel;
use crate::error::Result;
use crate::planner::Goal;
use crate::scenario::{self, LearnerSpec, ScenarioSpec};
use crate::selection::Heuristic;
use crate::sim::engine::Engine;

pub use crate::scenario::{BackendKind, SchedulerKind};

/// Which of the paper's applications to instantiate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AppKind {
    /// §6.1: solar-powered UV/eCO2/TVOC anomaly learner.
    AirQuality,
    /// §6.2: RF-powered RSSI human-presence learner.
    Presence,
    /// §6.3: piezo-powered vibration learner.
    Vibration,
}

impl AppKind {
    pub const ALL: [AppKind; 3] = [AppKind::AirQuality, AppKind::Presence, AppKind::Vibration];

    pub fn name(self) -> &'static str {
        match self {
            AppKind::AirQuality => "air_quality",
            AppKind::Presence => "presence",
            AppKind::Vibration => "vibration",
        }
    }

    pub fn parse(s: &str) -> Option<AppKind> {
        AppKind::ALL.iter().copied().find(|a| a.name() == s)
    }

    /// The paper preset for this app as a scenario spec.
    pub fn spec(self, seed: u64, horizon_us: u64) -> ScenarioSpec {
        scenario::preset(self.name(), seed, horizon_us).expect("paper presets exist")
    }

    /// The paper's cost table for this app's algorithm.
    pub fn cost_model(self) -> CostModel {
        self.spec(0, 3_600_000_000).cost.build()
    }

    /// Goal-state parameters (§4.2), per application cadence.
    pub fn goal(self) -> Goal {
        self.spec(0, 3_600_000_000).goal
    }
}

/// Legacy experiment configuration: (app × scheduler × heuristic ×
/// backend) plus the app-specific overrides. Thin: `to_spec` resolves it
/// to a [`ScenarioSpec`] and everything else delegates.
#[derive(Debug, Clone)]
pub struct AppConfig {
    pub kind: AppKind,
    pub seed: u64,
    pub horizon_us: u64,
    pub heuristic: Heuristic,
    pub scheduler: SchedulerKind,
    pub backend: BackendKind,
    /// Semi-supervised label budget (vibration app).
    pub label_budget: u32,
    /// Override the RF distance schedule (presence scenarios), meters.
    pub rf_distances: Option<Vec<(u64, f64)>>,
}

impl AppConfig {
    pub fn new(kind: AppKind, seed: u64, horizon_us: u64) -> Self {
        AppConfig {
            kind,
            seed,
            horizon_us,
            heuristic: Heuristic::RoundRobin,
            scheduler: SchedulerKind::Planner,
            backend: BackendKind::Native,
            label_budget: 30,
            rf_distances: None,
        }
    }

    /// Resolve to the declarative scenario spec.
    pub fn to_spec(&self) -> ScenarioSpec {
        let mut spec = self.kind.spec(self.seed, self.horizon_us);
        spec.scheduler = self.scheduler;
        spec.heuristic = self.heuristic;
        spec.backend = self.backend;
        if let LearnerSpec::ClusterLabel { label_budget } = &mut spec.learner {
            *label_budget = self.label_budget;
        }
        if let Some(sched) = &self.rf_distances {
            // pre-spec behavior: the override only applies to worlds with
            // an RF harvester / RSSI sensor and is silently ignored
            // elsewhere — keep that contract for this legacy struct
            let _ = spec.set_rf_distances(sched.clone());
        }
        spec
    }

    /// Wire everything into an engine.
    pub fn build_engine(&self) -> Result<Engine> {
        self.to_spec().build_engine()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const H: u64 = 3_600_000_000;

    #[test]
    fn all_apps_build_and_run_briefly() {
        for kind in AppKind::ALL {
            // the solar app sleeps until sunrise (~6 am), so give the
            // air-quality run enough horizon to see the sun
            let hours = if kind == AppKind::AirQuality { 12 } else { 2 };
            let mut cfg = AppConfig::new(kind, 7, hours * H);
            cfg.scheduler = SchedulerKind::Planner;
            let r = cfg.build_engine().unwrap().run().unwrap();
            assert!(r.cycles > 0, "{}: no cycles", kind.name());
            assert!(r.sensed > 0, "{}: no examples", kind.name());
        }
    }

    #[test]
    fn scheduler_kinds_build() {
        let goal = AppKind::Vibration.goal();
        for s in [
            SchedulerKind::Planner,
            SchedulerKind::Alpaca { learn_pct: 0.9 },
            SchedulerKind::Mayfly {
                learn_pct: 0.5,
                expiry_us: 1_000_000,
            },
        ] {
            let b = s.build(goal);
            assert!(!b.name().is_empty());
        }
    }

    #[test]
    fn app_kind_parse_round_trip() {
        for k in AppKind::ALL {
            assert_eq!(AppKind::parse(k.name()), Some(k));
        }
        assert_eq!(AppKind::parse("nope"), None);
    }

    #[test]
    fn app_config_overrides_reach_the_spec() {
        let mut cfg = AppConfig::new(AppKind::Vibration, 3, 2 * H);
        cfg.heuristic = Heuristic::Randomized;
        cfg.scheduler = SchedulerKind::Alpaca { learn_pct: 0.5 };
        cfg.label_budget = 7;
        let spec = cfg.to_spec();
        assert_eq!(spec.heuristic, Heuristic::Randomized);
        assert_eq!(spec.scheduler, SchedulerKind::Alpaca { learn_pct: 0.5 });
        assert_eq!(spec.learner, LearnerSpec::ClusterLabel { label_budget: 7 });
    }

    #[test]
    fn rf_distances_on_non_rf_app_is_ignored_not_fatal() {
        // legacy contract: the override only means something for RF worlds
        let mut cfg = AppConfig::new(AppKind::Vibration, 1, H);
        cfg.rf_distances = Some(vec![(0, 3.0)]);
        assert!(cfg.build_engine().is_ok());
    }

    #[test]
    fn rf_distance_override_applies() {
        let mut cfg = AppConfig::new(AppKind::Presence, 3, 9 * H);
        cfg.rf_distances = Some(vec![(0, 3.0), (3 * H, 5.0), (6 * H, 7.0)]);
        let h = cfg.to_spec().build_harvester();
        // power at 7 m (hour 7) should be far below power at 3 m (hour 1)
        let avg = |t0: u64| -> f64 {
            (0..60).map(|i| h.power_w(t0 + i * 1_000_000)).sum::<f64>() / 60.0
        };
        assert!(avg(H) > 3.0 * avg(7 * H));
    }
}
