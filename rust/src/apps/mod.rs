//! The three intermittent-learning applications of §6, assembled from the
//! substrate modules: air-quality (solar, k-NN), human presence (RF,
//! k-NN over RSSI), vibration (piezoelectric, NN-k-means cluster-then-
//! label). Each app bundles its harvester, capacitor, sensor world, cost
//! model, learner and goal parameters; `build_engine` wires a ready-to-run
//! [`crate::sim::engine::Engine`] for any (app × scheduler × heuristic ×
//! backend) combination — which is exactly the matrix §7 sweeps.

use crate::backend::native::NativeBackend;
use crate::backend::pjrt::PjrtBackend;
use crate::backend::ComputeBackend;
use crate::baselines::{DutyCycleScheduler, MayflyScheduler};
use crate::energy::harvester::{Harvester, Piezo, Rf, Solar};
use crate::energy::{Capacitor, CostModel};
use crate::error::Result;
use crate::learning::{ClusterLabelLearner, KnnAnomalyLearner, Learner};
use crate::planner::{DynamicActionPlanner, Goal, PlannerConfig};
use crate::selection::Heuristic;
use crate::sensors::accel::{Accel, MotionProfile};
use crate::sensors::{AirQuality, Rssi, Sensor};
use crate::sim::engine::Engine;
use crate::sim::{PlannerScheduler, Scheduler, SimConfig};

/// Which of the paper's applications to instantiate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AppKind {
    /// §6.1: solar-powered UV/eCO2/TVOC anomaly learner.
    AirQuality,
    /// §6.2: RF-powered RSSI human-presence learner.
    Presence,
    /// §6.3: piezo-powered vibration learner.
    Vibration,
}

impl AppKind {
    pub const ALL: [AppKind; 3] = [AppKind::AirQuality, AppKind::Presence, AppKind::Vibration];

    pub fn name(self) -> &'static str {
        match self {
            AppKind::AirQuality => "air_quality",
            AppKind::Presence => "presence",
            AppKind::Vibration => "vibration",
        }
    }

    pub fn parse(s: &str) -> Option<AppKind> {
        AppKind::ALL.iter().copied().find(|a| a.name() == s)
    }

    /// The paper's cost table for this app's algorithm.
    pub fn cost_model(self) -> CostModel {
        match self {
            AppKind::AirQuality => CostModel::knn(),
            AppKind::Presence => CostModel::knn_rssi(),
            AppKind::Vibration => CostModel::kmeans(),
        }
    }

    /// Goal-state parameters (§4.2), per application cadence.
    pub fn goal(self) -> Goal {
        match self {
            // slow world: modest learning rate; the environment drifts
            // (diurnal + seasonal), so learning never ends (n_learn = MAX:
            // lifelong adaptation — §4.2 notes the switch parameters are
            // application dependent)
            AppKind::AirQuality => Goal {
                rho_learn: 0.4,
                n_learn: u64::MAX,
                rho_infer: 0.8,
                window: 12,
            },
            // fast RF world: the device is mobile (area moves), so it must
            // keep learning forever to re-adapt — lifelong learning phase
            AppKind::Presence => Goal {
                rho_learn: 0.7,
                n_learn: u64::MAX,
                rho_infer: 1.2,
                window: 10,
            },
            AppKind::Vibration => Goal {
                rho_learn: 0.6,
                n_learn: 100,
                rho_infer: 1.0,
                window: 10,
            },
        }
    }
}

/// Scheduler selection for the experiment matrix.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SchedulerKind {
    /// The paper's dynamic action planner.
    Planner,
    /// Alpaca-style fixed duty cycle, `learn_pct` of examples learned.
    Alpaca { learn_pct: f64 },
    /// Mayfly-style duty cycle + data expiration.
    Mayfly { learn_pct: f64, expiry_us: u64 },
}

impl SchedulerKind {
    pub fn build(self, goal: Goal) -> Box<dyn Scheduler> {
        match self {
            SchedulerKind::Planner => Box::new(PlannerScheduler(DynamicActionPlanner::new(
                goal,
                PlannerConfig::default(),
            ))),
            SchedulerKind::Alpaca { learn_pct } => {
                Box::new(DutyCycleScheduler::new(learn_pct))
            }
            SchedulerKind::Mayfly {
                learn_pct,
                expiry_us,
            } => Box::new(MayflyScheduler::new(learn_pct, expiry_us)),
        }
    }

    pub fn label(self) -> String {
        match self {
            SchedulerKind::Planner => "intermittent_learning".into(),
            SchedulerKind::Alpaca { learn_pct } => {
                format!("alpaca_{}l", (learn_pct * 100.0) as u32)
            }
            SchedulerKind::Mayfly { learn_pct, .. } => {
                format!("mayfly_{}l", (learn_pct * 100.0) as u32)
            }
        }
    }
}

/// Compute-backend selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-rust math (fast; used for the big sweeps).
    Native,
    /// AOT HLO artifacts on the PJRT CPU client (full 3-layer stack).
    Pjrt,
}

impl BackendKind {
    pub fn build(self) -> Result<Box<dyn ComputeBackend>> {
        Ok(match self {
            BackendKind::Native => Box::new(NativeBackend::new()),
            BackendKind::Pjrt => Box::new(PjrtBackend::discover()?),
        })
    }
}

/// Full experiment configuration.
#[derive(Debug, Clone)]
pub struct AppConfig {
    pub kind: AppKind,
    pub seed: u64,
    pub horizon_us: u64,
    pub heuristic: Heuristic,
    pub scheduler: SchedulerKind,
    pub backend: BackendKind,
    /// Semi-supervised label budget (vibration app).
    pub label_budget: u32,
    /// Override the RF distance schedule (presence scenarios), meters.
    pub rf_distances: Option<Vec<(u64, f64)>>,
}

impl AppConfig {
    pub fn new(kind: AppKind, seed: u64, horizon_us: u64) -> Self {
        AppConfig {
            kind,
            seed,
            horizon_us,
            heuristic: Heuristic::RoundRobin,
            scheduler: SchedulerKind::Planner,
            backend: BackendKind::Native,
            label_budget: 30,
            rf_distances: None,
        }
    }

    /// The motion profile shared by the vibration sensor and harvester.
    pub fn motion_profile(&self) -> MotionProfile {
        let hours = (self.horizon_us / 3_600_000_000).max(1);
        MotionProfile::alternating_hours(1.2, 3.4, hours)
    }

    /// Build the sensor world.
    pub fn build_sensor(&self) -> Box<dyn Sensor> {
        match self.kind {
            AppKind::AirQuality => Box::new(AirQuality::new(self.seed, self.horizon_us)),
            AppKind::Presence => {
                let mut r = Rssi::three_areas(self.seed, self.horizon_us, self.horizon_us / 3);
                if let Some(sched) = &self.rf_distances {
                    // fig15(b) scenario: the device stays in one RF
                    // environment but its distance to the powered antenna
                    // changes. The human-presence perturbation rides on the
                    // same carrier, so its observable magnitude scales with
                    // the link budget (paper §7.4: "difficulty in learning
                    // RSSI patterns from weaker signals at a longer
                    // distance") — encode each distance step as an area
                    // with the same baseline but distance-scaled SNR.
                    let base = r.areas[0];
                    r.areas = sched
                        .iter()
                        .map(|&(start_us, d_m)| {
                            // received power scales with d^-2; the observable
                            // human perturbation rides on it
                            let scale = (3.0 / d_m.max(0.1)).powi(2).min(1.5);
                            crate::sensors::rssi::Area {
                                start_us,
                                base_dbm: base.base_dbm,
                                noise_db: base.noise_db,
                                human_db: base.human_db * scale,
                                human_shift_db: base.human_shift_db * scale,
                            }
                        })
                        .collect();
                }
                Box::new(r)
            }
            AppKind::Vibration => Box::new(Accel::new(self.motion_profile(), self.seed)),
        }
    }

    /// Build the harvester.
    pub fn build_harvester(&self) -> Box<dyn Harvester> {
        match self.kind {
            AppKind::AirQuality => Box::new(Solar {
                seed: self.seed ^ 0xA0,
                ..Solar::default()
            }),
            AppKind::Presence => {
                let mut rf = Rf {
                    seed: self.seed ^ 0xB0,
                    ..Rf::default()
                };
                if let Some(sched) = &self.rf_distances {
                    rf.schedule = sched.clone();
                }
                Box::new(rf)
            }
            AppKind::Vibration => Box::new(Piezo::new(self.motion_profile())),
        }
    }

    /// Build the capacitor (§6 platform parameters).
    pub fn build_capacitor(&self) -> Capacitor {
        match self.kind {
            AppKind::AirQuality => Capacitor::air_quality(),
            AppKind::Presence => Capacitor::presence(),
            AppKind::Vibration => Capacitor::vibration(),
        }
    }

    /// Build the learner.
    pub fn build_learner(&self) -> Box<dyn Learner> {
        match self.kind {
            AppKind::AirQuality | AppKind::Presence => Box::new(KnnAnomalyLearner::new()),
            AppKind::Vibration => {
                Box::new(ClusterLabelLearner::new(self.seed, self.label_budget))
            }
        }
    }

    /// Default simulation parameters for this horizon.
    pub fn sim_config(&self) -> SimConfig {
        SimConfig {
            seed: self.seed,
            horizon_us: self.horizon_us,
            eval_period_us: (self.horizon_us / 24).max(60_000_000),
            probe_count: 30,
            probe_lookback_us: match self.kind {
                // slow diurnal world: anomalies are hours apart
                AppKind::AirQuality => 6 * 3_600_000_000,
                // fast worlds: test against the last couple of hours
                _ => 2 * 3_600_000_000,
            },
            // The vibration world's energy arrives in 5 s gesture bursts;
            // a 60 s charging step would sample right past them. Solar/RF
            // power varies on minute scales, where 60 s is fine.
            charge_step_us: match self.kind {
                AppKind::Vibration => 1_000_000,
                _ => 60_000_000,
            },
        }
    }

    /// Wire everything into an engine.
    pub fn build_engine(&self) -> Result<Engine> {
        let goal = self.kind.goal();
        Ok(Engine::new(
            self.sim_config(),
            self.build_harvester(),
            self.build_capacitor(),
            self.build_sensor(),
            self.build_learner(),
            self.heuristic.build(self.seed ^ 0x5E1),
            self.scheduler.build(goal),
            self.backend.build()?,
            self.kind.cost_model(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const H: u64 = 3_600_000_000;

    #[test]
    fn all_apps_build_and_run_briefly() {
        for kind in AppKind::ALL {
            // the solar app sleeps until sunrise (~6 am), so give the
            // air-quality run enough horizon to see the sun
            let hours = if kind == AppKind::AirQuality { 12 } else { 2 };
            let mut cfg = AppConfig::new(kind, 7, hours * H);
            cfg.scheduler = SchedulerKind::Planner;
            let r = cfg.build_engine().unwrap().run().unwrap();
            assert!(r.cycles > 0, "{}: no cycles", kind.name());
            assert!(r.sensed > 0, "{}: no examples", kind.name());
        }
    }

    #[test]
    fn scheduler_kinds_build() {
        let goal = AppKind::Vibration.goal();
        for s in [
            SchedulerKind::Planner,
            SchedulerKind::Alpaca { learn_pct: 0.9 },
            SchedulerKind::Mayfly {
                learn_pct: 0.5,
                expiry_us: 1_000_000,
            },
        ] {
            let b = s.build(goal);
            assert!(!b.name().is_empty());
        }
    }

    #[test]
    fn app_kind_parse_round_trip() {
        for k in AppKind::ALL {
            assert_eq!(AppKind::parse(k.name()), Some(k));
        }
        assert_eq!(AppKind::parse("nope"), None);
    }

    #[test]
    fn labels_distinguish_duty_cycles() {
        assert_eq!(
            SchedulerKind::Alpaca { learn_pct: 0.9 }.label(),
            "alpaca_90l"
        );
        assert_eq!(
            SchedulerKind::Mayfly {
                learn_pct: 0.1,
                expiry_us: 1
            }
            .label(),
            "mayfly_10l"
        );
    }

    #[test]
    fn rf_distance_override_applies() {
        let mut cfg = AppConfig::new(AppKind::Presence, 3, 9 * H);
        cfg.rf_distances = Some(vec![(0, 3.0), (3 * H, 5.0), (6 * H, 7.0)]);
        let h = cfg.build_harvester();
        // power at 7 m (hour 7) should be far below power at 3 m (hour 1)
        let avg = |t0: u64| -> f64 {
            (0..60).map(|i| h.power_w(t0 + i * 1_000_000)).sum::<f64>() / 60.0
        };
        assert!(avg(H) > 3.0 * avg(7 * H));
    }
}
