//! Mayfly-style baseline scheduler (§7.1).
//!
//! Mayfly [Hester+ SenSys'17] adds *timeliness* to intermittent computing:
//! sensed data carries an expiration interval and is discarded when stale.
//! The paper's baseline configuration is the Alpaca duty-cycle schedule
//! plus this expiration rule — it still learns every (non-expired)
//! example and runs no action planner. As §7.4 notes, expiration can leave
//! the system with *nothing to learn* when energy finally arrives, which
//! is exactly the failure mode the intermittent-learning buffering avoids.

use crate::energy::cost::{ActionCost, CostModel};
use crate::planner::{PlanContext, Planned, Pending};
use crate::sim::Scheduler;

use super::alpaca::DutyCycleScheduler;

/// Alpaca schedule + data expiration.
#[derive(Debug, Clone)]
pub struct MayflyScheduler {
    inner: DutyCycleScheduler,
    /// Sensed data older than this is stale and dropped.
    pub expiry_us: u64,
}

impl MayflyScheduler {
    pub fn new(learn_pct: f64, expiry_us: u64) -> Self {
        MayflyScheduler {
            inner: DutyCycleScheduler::with_name(learn_pct, "mayfly"),
            expiry_us,
        }
    }
}

impl Scheduler for MayflyScheduler {
    fn next(&mut self, pending: &Pending, ctx: &PlanContext, costs: &CostModel) -> Planned {
        self.inner.next(pending, ctx, costs)
    }

    fn overhead(&self, _costs: &CostModel) -> ActionCost {
        // timestamp bookkeeping per decision (tiny, but not zero)
        ActionCost::new(2.0, 150, 1)
    }

    fn expiry_us(&self) -> Option<u64> {
        Some(self.expiry_us)
    }

    fn uses_selection(&self) -> bool {
        false
    }

    fn name(&self) -> &'static str {
        "mayfly"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::actions::Action;

    #[test]
    fn exposes_expiry() {
        let s = MayflyScheduler::new(0.5, 5_000_000);
        assert_eq!(s.expiry_us(), Some(5_000_000));
        assert!(!s.uses_selection());
        assert_eq!(s.name(), "mayfly");
    }

    #[test]
    fn schedule_matches_alpaca() {
        let costs = CostModel::knn();
        let ctx = PlanContext {
            learned_total: 0,
            quality: 0.0,
            window_learns: 0,
            window_infers: 0,
            window_cycle: 1,
            forecast_uj: None,
        };
        let mut m = MayflyScheduler::new(1.0, 1);
        let mut a = DutyCycleScheduler::new(1.0);
        for pending in [vec![], vec![Action::Sense], vec![Action::Extract]] {
            assert_eq!(
                m.next(&pending, &ctx, &costs),
                a.next(&pending, &ctx, &costs)
            );
        }
    }
}
