//! Running-mean RSSI threshold baseline (Fig. 7(c)).
//!
//! The paper compares the presence learner against "a baseline system that
//! uses a threshold changing over time based on the run-time mean of the
//! RSSI values". It keeps a running mean/variance of the windowed RSSI
//! level and flags presence when the current window's statistics deviate
//! by more than `k` sigma. It does not generalize across areas — after a
//! move, its long-memory mean is wrong for hours, which is what Fig. 7(c)
//! shows.

use crate::backend::ComputeBackend;
use crate::error::Result;
use crate::learning::{Example, Learner, Verdict};
use crate::nvm::Nvm;

/// Running mean ± k·std detector over one feature dimension.
#[derive(Debug, Clone)]
pub struct RunningMeanThreshold {
    /// Which feature of the example to track (0 = per-window mean).
    pub feature_idx: usize,
    /// Sigma multiplier.
    pub k: f32,
    /// EMA smoothing factor (long memory — the baseline's weakness).
    pub alpha: f32,
    mean: f32,
    var: f32,
    n: u64,
}

impl RunningMeanThreshold {
    pub fn new(feature_idx: usize, k: f32) -> Self {
        RunningMeanThreshold {
            feature_idx,
            k,
            alpha: 0.02,
            mean: 0.0,
            var: 0.0,
            n: 0,
        }
    }

    fn value(&self, ex: &Example) -> f32 {
        ex.features.get(self.feature_idx).copied().unwrap_or(0.0)
    }
}

impl Learner for RunningMeanThreshold {
    fn learn(&mut self, ex: &Example, _be: &mut dyn ComputeBackend) -> Result<()> {
        let x = self.value(ex);
        if self.n == 0 {
            self.mean = x;
            self.var = 0.0;
        } else {
            let d = x - self.mean;
            self.mean += self.alpha * d;
            self.var = (1.0 - self.alpha) * (self.var + self.alpha * d * d);
        }
        self.n += 1;
        Ok(())
    }

    fn infer(&mut self, ex: &Example, _be: &mut dyn ComputeBackend) -> Result<Verdict> {
        if self.n < 5 {
            return Ok(Verdict::Unknown);
        }
        let x = self.value(ex);
        let std = self.var.max(1e-12).sqrt();
        Ok(if (x - self.mean).abs() > self.k * std {
            Verdict::Abnormal
        } else {
            Verdict::Normal
        })
    }

    fn learnable(&self) -> bool {
        true
    }

    fn evaluate(&mut self, _be: &mut dyn ComputeBackend) -> Result<f32> {
        Ok(if self.n >= 5 { 0.5 } else { 0.0 })
    }

    fn learned_count(&self) -> u64 {
        self.n
    }

    fn save(&mut self, nvm: &mut Nvm) -> Result<()> {
        nvm.write_f32s("thr/state", &[self.mean, self.var])?;
        nvm.write_u64("thr/n", self.n)
    }

    fn restore(&mut self, nvm: &mut Nvm) -> Result<()> {
        if let Some(s) = nvm.read_f32s("thr/state") {
            if s.len() == 2 {
                self.mean = s[0];
                self.var = s[1];
            }
        }
        self.n = nvm.read_u64("thr/n");
        Ok(())
    }

    fn name(&self) -> &'static str {
        "running_mean_threshold"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::backend::shapes::FEAT_DIM;

    fn ex(v: f32) -> Example {
        let mut f = vec![0.0; FEAT_DIM];
        f[0] = v;
        Example::new(f, 0, false)
    }

    #[test]
    fn flags_large_deviation() {
        let mut be = NativeBackend::new();
        let mut t = RunningMeanThreshold::new(0, 3.0);
        for i in 0..100 {
            t.learn(&ex(1.0 + 0.1 * ((i % 7) as f32 - 3.0)), &mut be).unwrap();
        }
        assert_eq!(t.infer(&ex(1.0), &mut be).unwrap(), Verdict::Normal);
        assert_eq!(t.infer(&ex(10.0), &mut be).unwrap(), Verdict::Abnormal);
    }

    #[test]
    fn unknown_when_cold() {
        let mut be = NativeBackend::new();
        let mut t = RunningMeanThreshold::new(0, 3.0);
        assert_eq!(t.infer(&ex(1.0), &mut be).unwrap(), Verdict::Unknown);
    }

    #[test]
    fn long_memory_lags_after_level_shift() {
        // the baseline's documented weakness: after a mean shift, it keeps
        // flagging normal data as abnormal for a long time
        let mut be = NativeBackend::new();
        let mut t = RunningMeanThreshold::new(0, 3.0);
        for i in 0..200 {
            t.learn(&ex(1.0 + 0.05 * ((i % 5) as f32 - 2.0)), &mut be).unwrap();
        }
        // new area: level jumps to 5.0; immediately after the move the
        // baseline calls plain data abnormal
        assert_eq!(t.infer(&ex(5.0), &mut be).unwrap(), Verdict::Abnormal);
    }
}
