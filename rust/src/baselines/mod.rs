//! Baselines the paper evaluates against (§7.1, §7.2, §6.2):
//!
//! * [`alpaca`] — Alpaca-style task-based intermittent computing: a fixed,
//!   duty-cycled [sense, extract, learn|infer] schedule, no dynamic action
//!   planner, no example selection (§7.1).
//! * [`mayfly`] — Mayfly-style: Alpaca plus *data expiration* — sensed
//!   data older than an interval is discarded as stale (§7.1).
//! * [`threshold`] — the running-mean RSSI threshold detector the human
//!   presence learner is compared against in Fig. 7(c).
//! * [`offline`] — the three offline anomaly detectors of §7.2: one-class
//!   SVM (RBF), isolation forest, and an ARIMA(AR)-residual detector —
//!   each implemented from scratch.

pub mod alpaca;
pub mod mayfly;
pub mod offline;
pub mod threshold;

pub use alpaca::DutyCycleScheduler;
pub use mayfly::MayflyScheduler;
pub use threshold::RunningMeanThreshold;
