//! One-class SVM with RBF kernel (Schölkopf ν-SVM formulation), solved by
//! projected gradient descent on the dual:
//!
//!   min ½ αᵀKα   s.t.  0 ≤ αᵢ ≤ 1/(νn),  Σαᵢ = 1
//!
//! Decision function f(x) = Σᵢ αᵢ k(xᵢ, x) − ρ; x is an outlier iff
//! f(x) < 0. ρ is recovered from a margin support vector (0 < αᵢ < bound)
//! or, when none exists numerically, from the ν-quantile of the training
//! scores — which preserves the ν-fraction-outliers property the detector
//! is used for here.

use super::OfflineDetector;
use crate::util::stats;

/// RBF one-class SVM.
#[derive(Debug, Clone)]
pub struct OneClassSvm {
    /// Expected outlier fraction ν in (0, 1).
    pub nu: f64,
    /// RBF width γ (k(x,y) = exp(−γ‖x−y‖²)); `None` = 1/(dim·var) at fit.
    pub gamma: Option<f64>,
    /// Gradient iterations.
    pub iters: usize,
    alpha: Vec<f64>,
    support: Vec<Vec<f32>>,
    rho: f64,
    gamma_fit: f64,
}

impl OneClassSvm {
    pub fn new(nu: f64) -> Self {
        OneClassSvm {
            nu: nu.clamp(1e-3, 0.999),
            gamma: None,
            iters: 300,
            alpha: Vec::new(),
            support: Vec::new(),
            rho: 0.0,
            gamma_fit: 1.0,
        }
    }

    fn kernel(&self, a: &[f32], b: &[f32]) -> f64 {
        (-self.gamma_fit * stats::sq_euclidean(a, b) as f64).exp()
    }

    /// Raw decision value Σ αᵢ k(xᵢ, x) (before subtracting ρ).
    fn raw(&self, x: &[f32]) -> f64 {
        self.support
            .iter()
            .zip(&self.alpha)
            .map(|(s, &a)| a * self.kernel(s, x))
            .sum()
    }

    /// Project onto the simplex intersected with the box [0, ub]^n
    /// (Σα = 1): bisection on the shift τ of the thresholding operator.
    fn project(alpha: &mut [f64], ub: f64) {
        let clip = |v: f64| v.clamp(0.0, ub);
        let sum_at = |alpha: &[f64], tau: f64| -> f64 {
            alpha.iter().map(|&a| clip(a - tau)).sum()
        };
        let mut lo = alpha.iter().cloned().fold(f64::INFINITY, f64::min) - ub - 1.0;
        let mut hi = alpha.iter().cloned().fold(f64::NEG_INFINITY, f64::max) + 1.0;
        for _ in 0..60 {
            let mid = 0.5 * (lo + hi);
            if sum_at(alpha, mid) > 1.0 {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        let tau = 0.5 * (lo + hi);
        for a in alpha.iter_mut() {
            *a = clip(*a - tau);
        }
    }
}

impl OfflineDetector for OneClassSvm {
    fn fit(&mut self, data: &[Vec<f32>]) {
        let n = data.len();
        if n == 0 {
            return;
        }
        let dim = data[0].len();
        // default gamma = 1 / (dim * mean feature variance), sklearn-style
        self.gamma_fit = self.gamma.unwrap_or_else(|| {
            let mut var_sum = 0.0f64;
            for d in 0..dim {
                let col: Vec<f32> = data.iter().map(|r| r[d]).collect();
                let s = stats::std(&col) as f64;
                var_sum += s * s;
            }
            let v = (var_sum / dim as f64).max(1e-6);
            1.0 / (dim as f64 * v)
        });
        self.support = data.to_vec();

        // Gram matrix (n is capped by callers; O(n^2) memory)
        let mut k = vec![0.0f64; n * n];
        for i in 0..n {
            for j in i..n {
                let v = self.kernel(&data[i], &data[j]);
                k[i * n + j] = v;
                k[j * n + i] = v;
            }
        }

        let ub = 1.0 / (self.nu * n as f64);
        self.alpha = vec![1.0 / n as f64; n];
        Self::project(&mut self.alpha, ub);
        // projected gradient descent with diminishing step
        let mut grad = vec![0.0f64; n];
        for it in 0..self.iters {
            for i in 0..n {
                let row = &k[i * n..(i + 1) * n];
                grad[i] = row
                    .iter()
                    .zip(&self.alpha)
                    .map(|(&kij, &aj)| kij * aj)
                    .sum();
            }
            let step = 1.0 / (1.0 + it as f64 * 0.1);
            for i in 0..n {
                self.alpha[i] -= step * grad[i];
            }
            Self::project(&mut self.alpha, ub);
        }

        // rho from margin SVs; fall back to the nu-quantile of raw scores
        let margin: Vec<f64> = (0..n)
            .filter(|&i| self.alpha[i] > 1e-8 && self.alpha[i] < ub - 1e-8)
            .map(|i| self.raw(&data[i]))
            .collect();
        self.rho = if !margin.is_empty() {
            margin.iter().sum::<f64>() / margin.len() as f64
        } else {
            let mut raws: Vec<f32> = data.iter().map(|x| self.raw(x) as f32).collect();
            raws.sort_by(|a, b| a.total_cmp(b));
            let idx = ((self.nu * n as f64) as usize).min(n - 1);
            raws[idx] as f64
        };
    }

    fn score(&self, x: &[f32]) -> f32 {
        (self.rho - self.raw(x)) as f32 // higher = more anomalous
    }

    fn is_anomaly(&self, x: &[f32]) -> bool {
        self.score(x) > 0.0
    }

    fn name(&self) -> &'static str {
        "one_class_svm"
    }
}

#[cfg(test)]
mod tests {
    use super::super::{detector_accuracy, testdata};
    use super::*;

    #[test]
    fn separates_blob_from_outliers() {
        let (train, probes) = testdata::blob_with_outliers(1, 120, 60, 8);
        let mut svm = OneClassSvm::new(0.1);
        svm.fit(&train);
        let acc = detector_accuracy(&svm, &probes);
        assert!(acc >= 0.85, "acc {acc}");
    }

    #[test]
    fn nu_controls_training_outlier_fraction() {
        let (train, _) = testdata::blob_with_outliers(2, 150, 0, 6);
        for nu in [0.05, 0.2] {
            let mut svm = OneClassSvm::new(nu);
            svm.fit(&train);
            let out = train.iter().filter(|x| svm.is_anomaly(x)).count() as f64
                / train.len() as f64;
            assert!(
                (out - nu).abs() < 0.15,
                "nu {nu} -> training outlier fraction {out}"
            );
        }
    }

    #[test]
    fn projection_satisfies_constraints() {
        let mut a = vec![0.9, 0.5, -0.3, 0.1];
        OneClassSvm::project(&mut a, 0.5);
        let sum: f64 = a.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6, "sum {sum}");
        assert!(a.iter().all(|&v| (-1e-9..=0.5 + 1e-9).contains(&v)));
    }

    #[test]
    fn empty_fit_is_harmless() {
        let mut svm = OneClassSvm::new(0.1);
        svm.fit(&[]);
        assert!(!svm.is_anomaly(&[0.0; 4]) || svm.is_anomaly(&[0.0; 4]));
    }
}
