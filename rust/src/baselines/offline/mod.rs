//! Offline anomaly detectors (§7.2): one-class SVM with RBF kernel,
//! isolation forest, and an AR(IMA)-residual detector — all trained once
//! on the full example set (unlike the intermittent learner, which selects
//! and learns online under an energy budget).

pub mod arima;
pub mod iforest;
pub mod ocsvm;

pub use arima::ArDetector;
pub use iforest::IsolationForest;
pub use ocsvm::OneClassSvm;

/// Common interface: fit on unlabelled training vectors, then score test
/// vectors (higher = more anomalous) against a learned threshold.
pub trait OfflineDetector {
    /// Fit on (n, dim) row-major training data.
    fn fit(&mut self, data: &[Vec<f32>]);

    /// Anomaly score of one vector (comparable across calls after fit).
    fn score(&self, x: &[f32]) -> f32;

    /// Decision: is `x` anomalous?
    fn is_anomaly(&self, x: &[f32]) -> bool;

    fn name(&self) -> &'static str;
}

/// Accuracy of a detector over a labelled probe set.
pub fn detector_accuracy(
    det: &dyn OfflineDetector,
    probes: &[(Vec<f32>, bool)],
) -> f64 {
    if probes.is_empty() {
        return 0.0;
    }
    let ok = probes
        .iter()
        .filter(|(x, truth)| det.is_anomaly(x) == *truth)
        .count();
    ok as f64 / probes.len() as f64
}

#[cfg(test)]
pub(crate) mod testdata {
    use crate::util::Rng;

    /// Gaussian blob training set + labelled probes with far outliers.
    pub fn blob_with_outliers(
        seed: u64,
        n_train: usize,
        n_probe: usize,
        dim: usize,
    ) -> (Vec<Vec<f32>>, Vec<(Vec<f32>, bool)>) {
        let mut rng = Rng::new(seed);
        let mut point = |outlier: bool| -> Vec<f32> {
            (0..dim)
                .map(|_| {
                    let base = rng.normal(1.0, 0.5) as f32;
                    if outlier {
                        base + 8.0
                    } else {
                        base
                    }
                })
                .collect()
        };
        let train: Vec<Vec<f32>> = (0..n_train).map(|_| point(false)).collect();
        let probes: Vec<(Vec<f32>, bool)> = (0..n_probe)
            .map(|i| {
                let outlier = i % 2 == 1;
                (point(outlier), outlier)
            })
            .collect();
        (train, probes)
    }
}
