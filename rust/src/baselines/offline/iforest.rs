//! Isolation forest (Liu, Ting & Zhou, ICDM'08).
//!
//! Anomalies are isolated with fewer random axis-aligned splits than
//! inliers. Score s(x) = 2^(−E[h(x)] / c(ψ)); the decision threshold is
//! calibrated on the training scores at the configured contamination.

use super::OfflineDetector;
use crate::util::Rng;

/// A node of an isolation tree.
#[derive(Debug, Clone)]
enum Node {
    Leaf {
        size: usize,
    },
    Split {
        dim: usize,
        value: f32,
        left: Box<Node>,
        right: Box<Node>,
    },
}

/// Average unsuccessful-search path length of a BST with n nodes.
fn c(n: usize) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let n = n as f64;
    2.0 * ((n - 1.0).ln() + 0.5772156649) - 2.0 * (n - 1.0) / n
}

fn build(
    data: &mut [usize],
    points: &[Vec<f32>],
    depth: usize,
    max_depth: usize,
    rng: &mut Rng,
) -> Node {
    if data.len() <= 1 || depth >= max_depth {
        return Node::Leaf { size: data.len() };
    }
    let dim_count = points[data[0]].len();
    // pick a dim with spread; give up after a few tries
    for _ in 0..4 {
        let dim = rng.below_usize(dim_count);
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &i in data.iter() {
            lo = lo.min(points[i][dim]);
            hi = hi.max(points[i][dim]);
        }
        if hi <= lo {
            continue;
        }
        let value = lo + (hi - lo) * rng.f32();
        let mid = itertools_partition(data, |&i| points[i][dim] < value);
        if mid == 0 || mid == data.len() {
            continue;
        }
        let (l, r) = data.split_at_mut(mid);
        let left = Box::new(build(l, points, depth + 1, max_depth, rng));
        let right = Box::new(build(r, points, depth + 1, max_depth, rng));
        return Node::Split {
            dim,
            value,
            left,
            right,
        };
    }
    Node::Leaf { size: data.len() }
}

/// Stable partition in place; returns the split index.
fn itertools_partition<T, F: FnMut(&T) -> bool>(xs: &mut [T], mut pred: F) -> usize {
    let mut i = 0;
    for j in 0..xs.len() {
        if pred(&xs[j]) {
            xs.swap(i, j);
            i += 1;
        }
    }
    i
}

fn path_len(node: &Node, x: &[f32], depth: usize) -> f64 {
    match node {
        Node::Leaf { size } => depth as f64 + c(*size),
        Node::Split {
            dim,
            value,
            left,
            right,
        } => {
            if x[*dim] < *value {
                path_len(left, x, depth + 1)
            } else {
                path_len(right, x, depth + 1)
            }
        }
    }
}

/// The forest.
#[derive(Debug, Clone)]
pub struct IsolationForest {
    pub n_trees: usize,
    /// Subsample size ψ per tree (paper default 256).
    pub subsample: usize,
    /// Expected anomaly fraction for threshold calibration.
    pub contamination: f64,
    pub seed: u64,
    trees: Vec<Node>,
    psi: usize,
    threshold: f32,
}

impl IsolationForest {
    pub fn new(contamination: f64, seed: u64) -> Self {
        IsolationForest {
            n_trees: 100,
            subsample: 256,
            contamination: contamination.clamp(1e-3, 0.5),
            seed,
            trees: Vec::new(),
            psi: 0,
            threshold: 0.5,
        }
    }
}

impl OfflineDetector for IsolationForest {
    fn fit(&mut self, data: &[Vec<f32>]) {
        if data.is_empty() {
            return;
        }
        let mut rng = Rng::with_stream(self.seed, 0x1F0BE57);
        self.psi = self.subsample.min(data.len());
        let max_depth = (self.psi as f64).log2().ceil() as usize + 1;
        self.trees = (0..self.n_trees)
            .map(|_| {
                // subsample without replacement
                let mut idx: Vec<usize> = (0..data.len()).collect();
                rng.shuffle(&mut idx);
                idx.truncate(self.psi);
                build(&mut idx, data, 0, max_depth, &mut rng)
            })
            .collect();
        // calibrate threshold at the contamination quantile
        let mut scores: Vec<f32> = data.iter().map(|x| self.score(x)).collect();
        scores.sort_by(|a, b| b.total_cmp(a)); // descending
        let k = ((self.contamination * data.len() as f64) as usize).min(scores.len() - 1);
        self.threshold = scores[k];
    }

    fn score(&self, x: &[f32]) -> f32 {
        if self.trees.is_empty() {
            return 0.0;
        }
        let mean_path: f64 = self
            .trees
            .iter()
            .map(|t| path_len(t, x, 0))
            .sum::<f64>()
            / self.trees.len() as f64;
        (2.0f64.powf(-mean_path / c(self.psi).max(1e-9))) as f32
    }

    fn is_anomaly(&self, x: &[f32]) -> bool {
        self.score(x) > self.threshold
    }

    fn name(&self) -> &'static str {
        "isolation_forest"
    }
}

#[cfg(test)]
mod tests {
    use super::super::{detector_accuracy, testdata};
    use super::*;

    #[test]
    fn separates_blob_from_outliers() {
        let (train, probes) = testdata::blob_with_outliers(3, 256, 60, 8);
        let mut f = IsolationForest::new(0.05, 7);
        f.fit(&train);
        let acc = detector_accuracy(&f, &probes);
        assert!(acc >= 0.9, "acc {acc}");
    }

    #[test]
    fn outlier_scores_higher_than_inlier() {
        let (train, _) = testdata::blob_with_outliers(4, 200, 0, 4);
        let mut f = IsolationForest::new(0.1, 1);
        f.fit(&train);
        let inlier = vec![1.0f32; 4];
        let outlier = vec![30.0f32; 4];
        assert!(f.score(&outlier) > f.score(&inlier));
        assert!(f.score(&outlier) > 0.55);
    }

    #[test]
    fn c_monotone() {
        assert_eq!(c(1), 0.0);
        assert!(c(10) < c(100));
    }

    #[test]
    fn deterministic_given_seed() {
        let (train, probes) = testdata::blob_with_outliers(5, 128, 20, 4);
        let mut a = IsolationForest::new(0.1, 9);
        let mut b = IsolationForest::new(0.1, 9);
        a.fit(&train);
        b.fit(&train);
        for (x, _) in &probes {
            assert_eq!(a.score(x), b.score(x));
        }
    }
}
