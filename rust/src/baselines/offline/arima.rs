//! AR(IMA)-residual anomaly detector (§7.2's "ARIMA-based clustering").
//!
//! Fits an AR(p) model (optionally after d-th differencing — the "I" in
//! ARIMA; no MA term, as is standard for residual-based anomaly detection
//! on short embedded series) to each feature dimension's time series by
//! ordinary least squares, then flags a vector whose one-step-ahead
//! prediction residual exceeds k·σ in any dimension.

use super::OfflineDetector;

/// Per-dimension AR model.
#[derive(Debug, Clone, Default)]
struct ArDim {
    /// AR coefficients φ_1..φ_p plus intercept at the end.
    phi: Vec<f64>,
    /// Residual std on the training series.
    sigma: f64,
    /// Last p observed values (for one-step prediction at test time).
    tail: Vec<f64>,
}

/// AR(p) residual detector over multivariate series.
#[derive(Debug, Clone)]
pub struct ArDetector {
    pub p: usize,
    /// Differencing order (0 or 1).
    pub d: usize,
    /// Sigma multiplier for the anomaly gate.
    pub k: f64,
    dims: Vec<ArDim>,
}

impl ArDetector {
    pub fn new(p: usize, k: f64) -> Self {
        ArDetector {
            p: p.max(1),
            d: 0,
            k,
            dims: Vec::new(),
        }
    }

    fn difference(series: &[f64], d: usize) -> Vec<f64> {
        let mut s = series.to_vec();
        for _ in 0..d {
            s = s.windows(2).map(|w| w[1] - w[0]).collect();
        }
        s
    }

    /// OLS fit of x_t = c + Σ φ_i x_{t−i} + e_t via normal equations
    /// (p+1 unknowns, solved by Gaussian elimination).
    fn fit_dim(&self, series: &[f64]) -> ArDim {
        let s = Self::difference(series, self.d);
        let p = self.p;
        let n = s.len();
        let mut dim = ArDim {
            phi: vec![0.0; p + 1],
            sigma: 1e-6,
            tail: series[series.len().saturating_sub(p + self.d)..].to_vec(),
        };
        if n <= p + 2 {
            return dim;
        }
        let rows = n - p;
        let cols = p + 1; // lags + intercept
        // X^T X and X^T y
        let mut xtx = vec![0.0f64; cols * cols];
        let mut xty = vec![0.0f64; cols];
        for t in p..n {
            let mut row = Vec::with_capacity(cols);
            for i in 1..=p {
                row.push(s[t - i]);
            }
            row.push(1.0);
            for a in 0..cols {
                xty[a] += row[a] * s[t];
                for b in 0..cols {
                    xtx[a * cols + b] += row[a] * row[b];
                }
            }
        }
        // ridge for numerical safety
        for a in 0..cols {
            xtx[a * cols + a] += 1e-6;
        }
        if let Some(phi) = solve(&mut xtx, &mut xty, cols) {
            dim.phi = phi;
        }
        // residual sigma
        let mut sse = 0.0;
        for t in p..n {
            let mut pred = dim.phi[p];
            for i in 1..=p {
                pred += dim.phi[i - 1] * s[t - i];
            }
            let e = s[t] - pred;
            sse += e * e;
        }
        dim.sigma = (sse / rows as f64).sqrt().max(1e-6);
        dim
    }

    /// One-step residual of `x` given the training tail of dimension `d`.
    fn residual(&self, didx: usize, x: f64) -> f64 {
        let dim = &self.dims[didx];
        let raw_tail = &dim.tail;
        // reconstruct the differenced lags from the raw tail
        let mut series: Vec<f64> = raw_tail.clone();
        series.push(x);
        let s = Self::difference(&series, self.d);
        if s.len() < self.p + 1 {
            return 0.0;
        }
        let t = s.len() - 1;
        let mut pred = dim.phi[self.p];
        for i in 1..=self.p {
            pred += dim.phi[i - 1] * s[t - i];
        }
        (s[t] - pred) / dim.sigma
    }
}

/// Gaussian elimination with partial pivoting; returns the solution.
fn solve(a: &mut [f64], b: &mut [f64], n: usize) -> Option<Vec<f64>> {
    for col in 0..n {
        // pivot
        let mut piv = col;
        for r in (col + 1)..n {
            if a[r * n + col].abs() > a[piv * n + col].abs() {
                piv = r;
            }
        }
        if a[piv * n + col].abs() < 1e-12 {
            return None;
        }
        if piv != col {
            for c in 0..n {
                a.swap(col * n + c, piv * n + c);
            }
            b.swap(col, piv);
        }
        let d = a[col * n + col];
        for r in (col + 1)..n {
            let f = a[r * n + col] / d;
            for c in col..n {
                a[r * n + c] -= f * a[col * n + c];
            }
            b[r] -= f * b[col];
        }
    }
    let mut x = vec![0.0; n];
    for r in (0..n).rev() {
        let mut s = b[r];
        for c in (r + 1)..n {
            s -= a[r * n + c] * x[c];
        }
        x[r] = s / a[r * n + r];
    }
    Some(x)
}

impl OfflineDetector for ArDetector {
    /// Training data is interpreted as a time-ordered sequence of feature
    /// vectors; each dimension is fit independently.
    fn fit(&mut self, data: &[Vec<f32>]) {
        if data.is_empty() {
            return;
        }
        let dims = data[0].len();
        self.dims = (0..dims)
            .map(|d| {
                let series: Vec<f64> = data.iter().map(|r| r[d] as f64).collect();
                self.fit_dim(&series)
            })
            .collect();
    }

    fn score(&self, x: &[f32]) -> f32 {
        if self.dims.is_empty() {
            return 0.0;
        }
        // max normalized residual across dimensions
        (0..self.dims.len())
            .map(|d| self.residual(d, x[d] as f64).abs() as f32)
            .fold(0.0, f32::max)
    }

    fn is_anomaly(&self, x: &[f32]) -> bool {
        self.score(x) > self.k as f32
    }

    fn name(&self) -> &'static str {
        "arima"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn ar1_series(seed: u64, n: usize, phi: f64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        let mut x = 0.0f64;
        (0..n)
            .map(|_| {
                x = phi * x + rng.normal(0.0, 0.2);
                vec![x as f32, (x * 0.5) as f32]
            })
            .collect()
    }

    #[test]
    fn recovers_ar1_coefficient() {
        let det0 = ArDetector::new(1, 3.0);
        let data = ar1_series(1, 4000, 0.7);
        let series: Vec<f64> = data.iter().map(|r| r[0] as f64).collect();
        let dim = det0.fit_dim(&series);
        assert!((dim.phi[0] - 0.7).abs() < 0.07, "phi {:?}", dim.phi);
    }

    #[test]
    fn flags_residual_spikes() {
        let mut det = ArDetector::new(2, 3.5);
        let data = ar1_series(2, 800, 0.6);
        det.fit(&data);
        // continuation consistent with the process -> normal
        let last = data.last().unwrap()[0] as f64;
        let normal = vec![(0.6 * last) as f32, (0.3 * last) as f32];
        assert!(!det.is_anomaly(&normal));
        // a 10-sigma jump -> anomaly
        let spike = vec![(last + 5.0) as f32, ((last + 5.0) * 0.5) as f32];
        assert!(det.is_anomaly(&spike));
    }

    #[test]
    fn solver_solves_small_system() {
        // 2x + y = 5 ; x + 3y = 10  -> x = 1, y = 3
        let mut a = vec![2.0, 1.0, 1.0, 3.0];
        let mut b = vec![5.0, 10.0];
        let x = solve(&mut a, &mut b, 2).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-9 && (x[1] - 3.0).abs() < 1e-9);
    }

    #[test]
    fn differencing_reduces_length() {
        let s = [1.0, 3.0, 6.0, 10.0];
        assert_eq!(ArDetector::difference(&s, 1), vec![2.0, 3.0, 4.0]);
        assert_eq!(ArDetector::difference(&s, 2), vec![1.0, 1.0]);
    }

    #[test]
    fn short_series_is_harmless() {
        let mut det = ArDetector::new(3, 3.0);
        det.fit(&[vec![1.0, 2.0]]);
        assert_eq!(det.score(&[1.0, 2.0]), 0.0);
    }
}
