//! Alpaca-style baseline scheduler (§7.1).
//!
//! Alpaca [Maeng+ OOPSLA'17] is a task-based intermittent runtime with no
//! notion of machine-learning semantics: the paper's baseline configuration
//! repeats a *fixed* action sequence, duty-cycling `learn` vs `infer`
//! according to a compile-time parameter (e.g. [90% learn, 10% infer]),
//! executes every example (no selection heuristic) and runs no dynamic
//! action planner (zero scheduling overhead, but also zero adaptivity).
//!
//! Mapped onto the engine: one example in flight at a time, advancing
//! `sense → extract → learn|infer`; `learn` completion chains through
//! `evaluate` only implicitly (the baseline does not evaluate, so learn's
//! successor is handled by dropping the example — the scheduler advances
//! `learn`-state examples straight out via `Evaluate`-free termination is
//! impossible in the diagram, so we jump from `extract` directly to the
//! payload action; the engine does not re-enforce the diagram for
//! schedulers, which is exactly the point: Alpaca has no action diagram).

use crate::actions::Action;
use crate::energy::cost::{ActionCost, CostModel};
use crate::planner::{PlanContext, Planned, Pending};
use crate::sim::Scheduler;

/// Fixed duty-cycle schedule: `learn_pct` of examples are learned, the
/// rest inferred, in a deterministic interleave.
#[derive(Debug, Clone)]
pub struct DutyCycleScheduler {
    /// Fraction of examples sent to `learn` (0.1 / 0.5 / 0.9 in §7.1).
    pub learn_pct: f64,
    /// Deterministic interleave accumulator.
    acc: f64,
    /// Decision latched at `extract` completion for the current example.
    current_is_learn: bool,
    name: &'static str,
}

impl DutyCycleScheduler {
    pub fn new(learn_pct: f64) -> Self {
        DutyCycleScheduler {
            learn_pct,
            acc: 0.0,
            current_is_learn: false,
            name: "alpaca",
        }
    }

    pub(crate) fn with_name(learn_pct: f64, name: &'static str) -> Self {
        DutyCycleScheduler {
            name,
            ..Self::new(learn_pct)
        }
    }

    /// Advance the interleave: returns true if the next example should be
    /// learned (e.g. 0.9 -> 9 of every 10).
    fn next_is_learn(&mut self) -> bool {
        self.acc += self.learn_pct;
        if self.acc >= 1.0 - 1e-9 {
            self.acc -= 1.0;
            true
        } else {
            false
        }
    }

    /// The fixed-sequence step for a single pending example.
    pub(crate) fn step(&mut self, pending: &Pending) -> Planned {
        match pending.first() {
            None => Planned::SenseNew,
            Some(Action::Sense) => Planned::Advance {
                slot: 0,
                action: Action::Extract,
            },
            Some(Action::Extract) => {
                self.current_is_learn = self.next_is_learn();
                let action = if self.current_is_learn {
                    Action::Learn
                } else {
                    Action::Infer
                };
                Planned::Advance { slot: 0, action }
            }
            // learn completed: the example is done; evaluate is the only
            // diagram successor but Alpaca doesn't evaluate — emit Evaluate
            // as a zero-value terminal hop so the engine retires the slot.
            Some(Action::Learn) => Planned::Advance {
                slot: 0,
                action: Action::Evaluate,
            },
            // anything else (shouldn't happen): retire via infer path
            Some(_) => Planned::Advance {
                slot: 0,
                action: Action::Infer,
            },
        }
    }
}

impl Scheduler for DutyCycleScheduler {
    fn next(&mut self, pending: &Pending, _ctx: &PlanContext, _costs: &CostModel) -> Planned {
        self.step(pending)
    }

    fn overhead(&self, _costs: &CostModel) -> ActionCost {
        // hardcoded schedule: no planner overhead
        ActionCost::new(0.0, 0, 1)
    }

    fn uses_selection(&self) -> bool {
        false // every example is learned; no selection heuristic
    }

    fn name(&self) -> &'static str {
        self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> PlanContext {
        PlanContext {
            learned_total: 0,
            quality: 0.0,
            window_learns: 0,
            window_infers: 0,
            window_cycle: 1,
            forecast_uj: None,
        }
    }

    #[test]
    fn duty_cycle_interleave_ratio() {
        let mut s = DutyCycleScheduler::new(0.9);
        let learns = (0..1000).filter(|_| s.next_is_learn()).count();
        assert_eq!(learns, 900);
        let mut s = DutyCycleScheduler::new(0.1);
        let learns = (0..1000).filter(|_| s.next_is_learn()).count();
        assert_eq!(learns, 100);
    }

    #[test]
    fn fixed_sequence_shape() {
        let costs = CostModel::knn();
        let mut s = DutyCycleScheduler::new(1.0);
        assert_eq!(s.next(&vec![], &ctx(), &costs), Planned::SenseNew);
        assert_eq!(
            s.next(&vec![Action::Sense], &ctx(), &costs),
            Planned::Advance {
                slot: 0,
                action: Action::Extract
            }
        );
        assert_eq!(
            s.next(&vec![Action::Extract], &ctx(), &costs),
            Planned::Advance {
                slot: 0,
                action: Action::Learn
            }
        );
        // 0% learn -> infer
        let mut s = DutyCycleScheduler::new(0.0);
        assert_eq!(
            s.next(&vec![Action::Extract], &ctx(), &costs),
            Planned::Advance {
                slot: 0,
                action: Action::Infer
            }
        );
    }

    #[test]
    fn no_planner_overhead_and_no_selection() {
        let s = DutyCycleScheduler::new(0.5);
        assert_eq!(s.overhead(&CostModel::knn()).energy_uj, 0.0);
        assert!(!s.uses_selection());
    }
}
