//! Intermittent-safety analyzer: access-trace linting over the NVM
//! `KeyId` transaction layer.
//!
//! The paper's correctness story (§3.5) rests on two properties every
//! checkpoint path must uphold: actions are atomic (a mid-action power
//! failure replays to the committed pre-action state) and checkpoints are
//! complete (restore reconstructs exactly what save persisted). The
//! failure-injection tests spot-check those properties on fixed
//! schedules; this module checks them mechanically, in the spirit of the
//! GENESIS/SONIC toolchain (Gobieski et al., *Intelligence Beyond the
//! Edge*), which statically eliminates write-after-read hazards so
//! re-execution is always correct.
//!
//! The pipeline: arm the `Nvm` access recorder
//! ([`crate::nvm::audit`]), drive each learner (and the
//! [`RunState`](crate::sim::RunState) sweep-checkpoint store) through a
//! canonical learn / save / merge / power-fail / restore schedule, then
//! lint the recorded trace and the committed store against the rule
//! catalog:
//!
//! * [`RULE_WAR`] `IL-WAR` — inside one action, a *partial* write overlaps
//!   bytes read from committed pre-action state earlier in the same
//!   action. Replaying the action after a mid-action power failure would
//!   read post-write state and diverge. Whole-value overwrites are exempt:
//!   the read-counter-then-rewrite-it idiom (generation counters, head
//!   blobs) replays cleanly because the rewrite does not depend on
//!   partially-written state surviving.
//! * [`RULE_ATOM`] `IL-ATOM` — a write landed outside a `begin_action` /
//!   `commit_action` bracket, so a power failure can tear it.
//! * [`RULE_DELTA`] `IL-DELTA` — after a committed `save_delta`, the
//!   store's committed bytes diverge from an identically-fed full-save
//!   twin: the learner's dirty tracking under-declared what changed.
//! * [`RULE_PARITY`] `IL-PARITY` — a key holding committed state is never
//!   read back by the restore path: state silently lost across a reboot.
//!
//! Recording needs `cfg(debug_assertions)`, so the analyzer runs in dev
//! builds (`cargo run -- analyze ...`, `cargo test`); a release binary
//! reports a configuration error instead of a vacuously clean report.

use std::collections::{BTreeMap, BTreeSet};

use crate::backend::ComputeBackend;
use crate::error::{Error, Result};
use crate::learning::{ClusterLabelLearner, Example, KnnAnomalyLearner, Learner};
use crate::nvm::audit::{normalize, overlap, AccessEvent, AccessTrace};
use crate::nvm::Nvm;
use crate::scenario::{preset, BackendKind, ScenarioSpec};
use crate::util::json::Json;
use crate::util::Rng;

use crate::backend::shapes::FEAT_DIM;

/// Write-after-read hazard inside one action.
pub const RULE_WAR: &str = "IL-WAR";
/// Write outside a begin/commit action bracket.
pub const RULE_ATOM: &str = "IL-ATOM";
/// Delta checkpoint diverges from the full-save twin.
pub const RULE_DELTA: &str = "IL-DELTA";
/// Saved key never read back by restore.
pub const RULE_PARITY: &str = "IL-PARITY";

/// One analyzer finding: a rule violation on a key, with the offending
/// byte range where one exists.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    pub key: String,
    pub range: Option<(usize, usize)>,
    pub detail: String,
}

impl Finding {
    fn to_json(&self) -> Json {
        let range = match self.range {
            Some((s, e)) => Json::Arr(vec![Json::Num(s as f64), Json::Num(e as f64)]),
            None => Json::Null,
        };
        Json::obj(vec![
            ("rule", Json::Str(self.rule.to_string())),
            ("key", Json::Str(self.key.clone())),
            ("range", range),
            ("detail", Json::Str(self.detail.clone())),
        ])
    }
}

/// Findings for one checkpointing path (learner × backend, or the
/// run-state store).
#[derive(Debug, Clone)]
pub struct Entry {
    pub learner: String,
    pub backend: String,
    pub findings: Vec<Finding>,
}

impl Entry {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("learner", Json::Str(self.learner.clone())),
            ("backend", Json::Str(self.backend.clone())),
            (
                "findings",
                Json::Arr(self.findings.iter().map(Finding::to_json).collect()),
            ),
        ])
    }
}

/// Machine-readable analyzer report for one scenario preset.
#[derive(Debug, Clone)]
pub struct Report {
    pub scenario: String,
    pub entries: Vec<Entry>,
}

impl Report {
    pub fn findings_total(&self) -> usize {
        self.entries.iter().map(|e| e.findings.len()).sum()
    }

    pub fn is_clean(&self) -> bool {
        self.findings_total() == 0
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("scenario", Json::Str(self.scenario.clone())),
            ("findings_total", Json::Num(self.findings_total() as f64)),
            (
                "entries",
                Json::Arr(self.entries.iter().map(Entry::to_json).collect()),
            ),
        ])
    }
}

/// Keep the first finding per (rule, key) — one schedule can trip the
/// same hazard dozens of times.
fn dedup(findings: Vec<Finding>) -> Vec<Finding> {
    let mut seen = BTreeSet::new();
    findings
        .into_iter()
        .filter(|f| seen.insert((f.rule, f.key.clone())))
        .collect()
}

/// Lint one access trace for WAR hazards and unbracketed writes. Pure
/// over the trace, so test schedules can assert on it directly.
pub fn lint_trace(trace: &AccessTrace) -> Vec<Finding> {
    let mut findings = Vec::new();
    // committed-observed read ranges per key, within the open action
    let mut reads: BTreeMap<&str, Vec<(usize, usize)>> = BTreeMap::new();
    for ev in &trace.events {
        match ev {
            AccessEvent::Begin | AccessEvent::Commit | AccessEvent::Abort => reads.clear(),
            AccessEvent::Read {
                key,
                committed,
                in_txn,
                ..
            } => {
                if *in_txn && !committed.is_empty() {
                    reads
                        .entry(key.as_str())
                        .or_default()
                        .extend(committed.iter().copied());
                }
            }
            AccessEvent::Write {
                key,
                range,
                full,
                in_txn,
            } => {
                if !*in_txn {
                    findings.push(Finding {
                        rule: RULE_ATOM,
                        key: key.clone(),
                        range: Some(*range),
                        detail: "write landed outside a begin/commit action bracket \
                                 (a power failure can tear it)"
                            .into(),
                    });
                } else if !*full {
                    let seen = reads.get(key.as_str()).map(|v| v.as_slice()).unwrap_or(&[]);
                    if let Some(hit) = overlap(*range, seen) {
                        findings.push(Finding {
                            rule: RULE_WAR,
                            key: key.clone(),
                            range: Some(hit),
                            detail: format!(
                                "partial write over bytes {}..{} read from committed state \
                                 earlier in the same action — replay after a mid-action \
                                 power failure diverges",
                                hit.0, hit.1
                            ),
                        });
                    }
                }
            }
            // commit persist steps and recovery heals are the store's own
            // machinery, not application accesses — nothing to lint
            AccessEvent::Flush { .. } | AccessEvent::Record { .. } | AccessEvent::Heal { .. } => {}
        }
    }
    dedup(findings)
}

/// Byte-compare every committed key of the delta store against the
/// full-save twin (the `IL-DELTA` oracle). `declared` carries the dirty
/// ranges the delta save staged, for the report.
fn compare_stores(
    nvm: &Nvm,
    shadow: &Nvm,
    declared: &[(String, Vec<(usize, usize)>)],
) -> Vec<Finding> {
    let mut names: BTreeSet<&str> = nvm.keys().map(|(k, _)| k).collect();
    names.extend(shadow.keys().map(|(k, _)| k));
    let mut findings = Vec::new();
    for name in names {
        let got = nvm
            .resolve(name)
            .and_then(|id| nvm.committed_id(id))
            .unwrap_or(&[]);
        let want = shadow
            .resolve(name)
            .and_then(|id| shadow.committed_id(id))
            .unwrap_or(&[]);
        if got == want {
            continue;
        }
        let lo = got.iter().zip(want).take_while(|(a, b)| a == b).count();
        let hi = got.len().max(want.len());
        let ranges = declared
            .iter()
            .find(|(k, _)| k.as_str() == name)
            .map(|(_, r)| r.clone())
            .unwrap_or_default();
        findings.push(Finding {
            rule: RULE_DELTA,
            key: name.to_string(),
            range: Some((lo, hi)),
            detail: format!(
                "delta-saved committed state diverges from the full-save twin from \
                 byte {lo}; declared dirty ranges {ranges:?} do not cover every \
                 changed byte"
            ),
        });
    }
    findings
}

/// Every key holding committed state must be read by the restore pass
/// whose trace is given (the `IL-PARITY` rule).
fn check_parity(nvm: &Nvm, restore_trace: &AccessTrace) -> Vec<Finding> {
    let read: BTreeSet<&str> = restore_trace
        .events
        .iter()
        .filter_map(|ev| match ev {
            AccessEvent::Read { key, .. } => Some(key.as_str()),
            _ => None,
        })
        .collect();
    let mut findings = Vec::new();
    for (name, id) in nvm.keys() {
        if nvm.committed_id(id).is_some() && !read.contains(name) {
            findings.push(Finding {
                rule: RULE_PARITY,
                key: name.to_string(),
                range: None,
                detail: "saved key never read back by restore — state silently \
                         lost across a reboot"
                    .into(),
            });
        }
    }
    findings
}

/// A two-population synthetic example (mirrors the feature layout the
/// kmeans and failure-injection tests train on): 8 hot features at base
/// 0 (normal) or 8 (abnormal), the rest zero.
fn synth_example(rng: &mut Rng, t_us: u64, abnormal: bool) -> Example {
    let mut f = vec![0.0f32; FEAT_DIM];
    let base = if abnormal { 8 } else { 0 };
    for x in f.iter_mut().skip(base).take(8) {
        *x = 2.0 + rng.normal(0.0, 0.2) as f32;
    }
    Example::new(f, t_us, abnormal)
}

/// Drive one learner family through the canonical schedule under the
/// recorder and return every finding: ~40 steps of learn (plus two merge
/// legs fed by a separately trained donor), each followed by a
/// `save_delta` that either commits — and is byte-compared against an
/// identically-fed full-save twin — or power-fails mid-save (abort +
/// reboot + restore on both stores), then a final fresh-learner restore
/// whose trace is linted and parity-checked.
fn analyze_learner(
    make: &dyn Fn(u64) -> Box<dyn Learner>,
    be: &mut dyn ComputeBackend,
    seed: u64,
) -> Result<Vec<Finding>> {
    let mut findings = Vec::new();
    let mut main = make(seed);
    let mut twin = make(seed);
    let mut nvm = Nvm::new();
    let mut shadow = Nvm::new();

    // the merge legs adopt state from a separately trained donor
    let mut donor = make(seed + 1);
    let mut donor_rng = Rng::with_stream(seed, 0xD0); // donor examples
    for i in 0..16u64 {
        let ex = synth_example(&mut donor_rng, i * 250_000, i % 2 == 1);
        donor.learn(&ex, be)?;
    }
    let dsnap = donor.snapshot();

    // boot checkpoint: every later restore finds a committed snapshot
    nvm.audit_start();
    nvm.begin_action()?;
    main.save(&mut nvm)?;
    nvm.commit_action()?;
    shadow.begin_action()?;
    twin.save(&mut shadow)?;
    shadow.commit_action()?;

    let mut rng = Rng::with_stream(seed, 0x5C); // schedule randomness
    for step in 0..40u64 {
        let now_us = (step + 1) * 500_000;
        if step == 13 || step == 29 {
            if let Some(s) = &dsnap {
                main.merge(&[s], be, now_us, None)?;
                twin.merge(&[s], be, now_us, None)?;
            }
        } else {
            let ex = synth_example(&mut rng, now_us, step % 2 == 0);
            main.learn(&ex, be)?;
            twin.learn(&ex, be)?;
        }
        if rng.f32() < 0.25 {
            // power failure mid-save: abort, reboot, restore — mirrored
            nvm.begin_action()?;
            main.save_delta(&mut nvm)?;
            nvm.abort_action();
            shadow.begin_action()?;
            twin.save(&mut shadow)?;
            shadow.abort_action();
            main = make(seed);
            twin = make(seed);
            main.restore(&mut nvm)?;
            twin.restore(&mut shadow)?;
        } else {
            nvm.begin_action()?;
            main.save_delta(&mut nvm)?;
            let declared: Vec<(String, Vec<(usize, usize)>)> = nvm
                .keys()
                .map(|(k, id)| (k.to_string(), normalize(nvm.staged_dirty(id).to_vec())))
                .collect();
            nvm.commit_action()?;
            shadow.begin_action()?;
            twin.save(&mut shadow)?;
            shadow.commit_action()?;
            findings.extend(compare_stores(&nvm, &shadow, &declared));
        }
    }
    if let Some(trace) = nvm.audit_take() {
        findings.extend(lint_trace(&trace));
    }

    // restore parity: a fresh learner must read back every committed key
    let mut fresh = make(seed);
    nvm.audit_start();
    fresh.restore(&mut nvm)?;
    let trace = nvm.audit_take().unwrap_or_default();
    findings.extend(lint_trace(&trace));
    findings.extend(check_parity(&nvm, &trace));
    Ok(dedup(findings))
}

/// Drive the [`RunState`](crate::sim::RunState) sweep-checkpoint store
/// through an incremental save schedule with torn (aborted) saves, then
/// lint the trace and check restore parity the same way.
fn analyze_run_state(seed: u64) -> Result<Vec<Finding>> {
    use crate::actions::Action;
    use crate::energy::EnergyMeter;
    use crate::sim::{Checkpoint, RunResult, RunState};

    let mut findings = Vec::new();
    let mut nvm = Nvm::new();
    let mut state = RunState::new();
    let mut result = RunResult {
        scheduler: "intermittent_learning".into(),
        ..Default::default()
    };
    let mut meter = EnergyMeter::new();
    let mut rng = Rng::with_stream(seed, 0xA0); // torn-save schedule
    nvm.audit_start();
    for i in 0..24u64 {
        meter.record_action(Action::Learn, 9_309.0, 1_551_000);
        meter.record("planner", 57.0, 4_300);
        meter.sample(i * 1_000_000);
        result.learned += 1;
        result.sensed += 2;
        result.cycles += 3;
        result.infer_log.push((i * 500_000, i % 2 == 0, i % 3 == 0));
        result.checkpoints.push(Checkpoint {
            t_us: i * 1_000_000,
            accuracy: 0.5 + 0.01 * i as f64,
            learned: result.learned,
            inferred: result.inferred,
            energy_uj: meter.total_uj(),
            voltage: 3.0,
        });
        nvm.begin_action()?;
        state.save(&mut nvm, &result, &meter)?;
        if rng.f32() < 0.25 {
            nvm.abort_action(); // torn save: the next one self-heals
        } else {
            nvm.commit_action()?;
        }
    }
    nvm.begin_action()?;
    state.save(&mut nvm, &result, &meter)?;
    nvm.commit_action()?;
    if let Some(trace) = nvm.audit_take() {
        findings.extend(lint_trace(&trace));
    }

    // a fresh RunState adopting the store must read every committed key
    let mut adopter = RunState::new();
    nvm.audit_start();
    adopter.restore(&mut nvm)?;
    let trace = nvm.audit_take().unwrap_or_default();
    findings.extend(lint_trace(&trace));
    findings.extend(check_parity(&nvm, &trace));
    Ok(dedup(findings))
}

/// Backends the analyzer exercises (compiled-in ones only, so reports —
/// and the committed goldens — are stable across default builds).
fn backend_names() -> &'static [&'static str] {
    if cfg!(feature = "pjrt") {
        &["native", "pjrt"]
    } else {
        &["native"]
    }
}

/// Analyze every learner family × backend (plus the run-state store)
/// under `spec`'s name and seed.
pub fn analyze_spec(spec: &ScenarioSpec) -> Result<Report> {
    if !cfg!(debug_assertions) {
        return Err(Error::Config(
            "the intermittent-safety analyzer needs the debug-assertions access \
             recorder; run it through a dev-profile build (`cargo run -- analyze ...`)"
                .into(),
        ));
    }
    let mut entries = Vec::new();
    for kind in ["knn", "cluster_label"] {
        for be_name in backend_names() {
            let mut be = BackendKind::parse(be_name)
                .ok_or_else(|| Error::Config(format!("unknown backend `{be_name}`")))?
                .build()?;
            let make: Box<dyn Fn(u64) -> Box<dyn Learner>> = match kind {
                "knn" => Box::new(|_seed| Box::new(KnnAnomalyLearner::new()) as Box<dyn Learner>),
                _ => Box::new(|seed| {
                    Box::new(ClusterLabelLearner::new(seed, 64)) as Box<dyn Learner>
                }),
            };
            entries.push(Entry {
                learner: kind.to_string(),
                backend: be_name.to_string(),
                findings: analyze_learner(make.as_ref(), be.as_mut(), spec.seed)?,
            });
        }
    }
    entries.push(Entry {
        learner: "run_state".to_string(),
        backend: "-".to_string(),
        findings: analyze_run_state(spec.seed)?,
    });
    Ok(Report {
        scenario: spec.name.clone(),
        entries,
    })
}

/// Analyze a named paper preset (the CLI / CI entry point).
pub fn analyze_preset(name: &str) -> Result<Report> {
    let spec = preset(name, 42, 3_600_000_000)?;
    analyze_spec(&spec)
}

#[cfg(test)]
pub(crate) mod fixtures {
    //! Seeded-bug learners: each plants exactly one hazard class the
    //! analyzer must flag (and the shipped learners must not share).

    use super::*;
    use crate::learning::Verdict;

    /// Reads its committed row then partially rewrites it inside the same
    /// action: the textbook WAR hazard (`IL-WAR`).
    pub struct WarLearner {
        state: Vec<f32>,
        learned: u64,
    }

    impl Default for WarLearner {
        fn default() -> Self {
            WarLearner {
                state: vec![0.0; 4],
                learned: 0,
            }
        }
    }

    impl Learner for WarLearner {
        fn learn(&mut self, ex: &Example, _be: &mut dyn ComputeBackend) -> Result<()> {
            let i = (self.learned % 4) as usize;
            self.state[i] = ex.features.first().copied().unwrap_or(0.0) + self.learned as f32;
            self.learned += 1;
            Ok(())
        }

        fn infer(&mut self, _ex: &Example, _be: &mut dyn ComputeBackend) -> Result<Verdict> {
            Ok(Verdict::Unknown)
        }

        fn learnable(&self) -> bool {
            true
        }

        fn evaluate(&mut self, _be: &mut dyn ComputeBackend) -> Result<f32> {
            Ok(0.0)
        }

        fn learned_count(&self) -> u64 {
            self.learned
        }

        fn save(&mut self, nvm: &mut Nvm) -> Result<()> {
            nvm.write_f32s("war/state", &self.state)
        }

        fn save_delta(&mut self, nvm: &mut Nvm) -> Result<()> {
            // read-modify-write of the committed row in one action
            let id = nvm.intern("war/state");
            let _ = nvm.read_f32s_id(id);
            nvm.write_f32s_at(id, 0, &self.state)
        }

        fn restore(&mut self, nvm: &mut Nvm) -> Result<()> {
            if let Some(xs) = nvm.read_f32s("war/state") {
                if xs.len() == 4 {
                    self.state = xs;
                }
            }
            Ok(())
        }

        fn name(&self) -> &'static str {
            "war_fixture"
        }
    }

    /// Mutates its whole state on every learn but declares only the first
    /// element dirty: an under-declared delta checkpoint (`IL-DELTA`).
    pub struct UnderDeltaLearner {
        state: Vec<f32>,
        learned: u64,
    }

    impl Default for UnderDeltaLearner {
        fn default() -> Self {
            UnderDeltaLearner {
                state: vec![0.0; 4],
                learned: 0,
            }
        }
    }

    impl Learner for UnderDeltaLearner {
        fn learn(&mut self, ex: &Example, _be: &mut dyn ComputeBackend) -> Result<()> {
            for (i, x) in self.state.iter_mut().enumerate() {
                *x += ex.features.get(i).copied().unwrap_or(0.0) + 1.0;
            }
            self.learned += 1;
            Ok(())
        }

        fn infer(&mut self, _ex: &Example, _be: &mut dyn ComputeBackend) -> Result<Verdict> {
            Ok(Verdict::Unknown)
        }

        fn learnable(&self) -> bool {
            true
        }

        fn evaluate(&mut self, _be: &mut dyn ComputeBackend) -> Result<f32> {
            Ok(0.0)
        }

        fn learned_count(&self) -> u64 {
            self.learned
        }

        fn save(&mut self, nvm: &mut Nvm) -> Result<()> {
            nvm.write_f32s("under/state", &self.state)
        }

        fn save_delta(&mut self, nvm: &mut Nvm) -> Result<()> {
            let id = nvm.intern("under/state");
            nvm.write_f32s_at(id, 0, &self.state[..1])
        }

        fn restore(&mut self, nvm: &mut Nvm) -> Result<()> {
            if let Some(xs) = nvm.read_f32s("under/state") {
                if xs.len() == 4 {
                    self.state = xs;
                }
            }
            Ok(())
        }

        fn name(&self) -> &'static str {
            "under_delta_fixture"
        }
    }

    /// Writes a bookkeeping key outside any action bracket during restore
    /// (`IL-ATOM`) — and reads it back, so parity stays clean.
    pub struct StrayWriteLearner {
        state: Vec<f32>,
        learned: u64,
    }

    impl Default for StrayWriteLearner {
        fn default() -> Self {
            StrayWriteLearner {
                state: vec![0.0; 4],
                learned: 0,
            }
        }
    }

    impl Learner for StrayWriteLearner {
        fn learn(&mut self, ex: &Example, _be: &mut dyn ComputeBackend) -> Result<()> {
            let i = (self.learned % 4) as usize;
            self.state[i] = ex.features.first().copied().unwrap_or(0.0);
            self.learned += 1;
            Ok(())
        }

        fn infer(&mut self, _ex: &Example, _be: &mut dyn ComputeBackend) -> Result<Verdict> {
            Ok(Verdict::Unknown)
        }

        fn learnable(&self) -> bool {
            true
        }

        fn evaluate(&mut self, _be: &mut dyn ComputeBackend) -> Result<f32> {
            Ok(0.0)
        }

        fn learned_count(&self) -> u64 {
            self.learned
        }

        fn save(&mut self, nvm: &mut Nvm) -> Result<()> {
            nvm.write_f32s("stray/state", &self.state)
        }

        fn restore(&mut self, nvm: &mut Nvm) -> Result<()> {
            if let Some(xs) = nvm.read_f32s("stray/state") {
                if xs.len() == 4 {
                    self.state = xs;
                }
            }
            // bug: boot bookkeeping outside any action bracket (but read
            // back afterwards, so only atomicity is violated, not parity)
            let boots = nvm.read_u64("stray/boots");
            nvm.write_u64("stray/boots", boots + 1)?;
            let _ = nvm.read_u64("stray/boots");
            Ok(())
        }

        fn name(&self) -> &'static str {
            "stray_write_fixture"
        }
    }

    /// Drive a store through a torn commit and its self-heal under the
    /// recorder: the first slot flushes durably, the second tears
    /// mid-flush, and recovery rolls the transaction back. The returned
    /// trace is the auditor's view of one detect-and-heal cycle —
    /// `Flush` for the completed persist step, no `Record` (the cut
    /// landed before it), then `Heal { rolled_back: true }`.
    pub fn healed_rollback_trace() -> AccessTrace {
        use crate::fault::FaultPoint;
        use crate::nvm::Recovery;

        let mut nvm = Nvm::new();
        nvm.write("fix/a", &[1u8; 8]).unwrap();
        nvm.write("fix/b", &[2u8; 8]).unwrap();
        nvm.audit_start();
        nvm.begin_action().unwrap();
        nvm.write("fix/a", &[9u8; 8]).unwrap();
        nvm.write("fix/b", &[8u8; 8]).unwrap();
        nvm.fault_mut().arm(FaultPoint::Tear { step: 1, offset: 3 });
        assert!(nvm.commit_action().is_err());
        nvm.power_failure_reset();
        assert_eq!(nvm.recover(), Recovery::RolledBack);
        nvm.audit_take().unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::fixtures::{StrayWriteLearner, UnderDeltaLearner, WarLearner};
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::scenario::PRESETS;

    fn rules(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn war_fixture_flagged_with_the_war_rule() {
        let mut be = NativeBackend::new();
        let make = |_: u64| Box::new(WarLearner::default()) as Box<dyn Learner>;
        let findings = analyze_learner(&make, &mut be, 7).unwrap();
        assert!(
            findings.iter().any(|f| f.rule == RULE_WAR && f.key == "war/state"),
            "{findings:?}"
        );
        assert!(!rules(&findings).contains(&RULE_DELTA), "{findings:?}");
        assert!(!rules(&findings).contains(&RULE_ATOM), "{findings:?}");
    }

    #[test]
    fn under_declared_delta_flagged_with_the_delta_rule() {
        let mut be = NativeBackend::new();
        let make = |_: u64| Box::new(UnderDeltaLearner::default()) as Box<dyn Learner>;
        let findings = analyze_learner(&make, &mut be, 7).unwrap();
        assert!(
            findings.iter().any(|f| f.rule == RULE_DELTA && f.key == "under/state"),
            "{findings:?}"
        );
        assert!(!rules(&findings).contains(&RULE_WAR), "{findings:?}");
    }

    #[test]
    fn stray_write_flagged_with_the_atomicity_rule() {
        let mut be = NativeBackend::new();
        let make = |_: u64| Box::new(StrayWriteLearner::default()) as Box<dyn Learner>;
        let findings = analyze_learner(&make, &mut be, 7).unwrap();
        assert!(
            findings.iter().any(|f| f.rule == RULE_ATOM && f.key == "stray/boots"),
            "{findings:?}"
        );
        // it reads the stray key back, so parity must not also fire
        assert!(!rules(&findings).contains(&RULE_PARITY), "{findings:?}");
    }

    #[test]
    fn unrestored_key_flagged_with_the_parity_rule() {
        struct ForgetfulLearner {
            state: Vec<f32>,
            learned: u64,
        }
        impl Learner for ForgetfulLearner {
            fn learn(&mut self, _ex: &Example, _be: &mut dyn ComputeBackend) -> Result<()> {
                self.state[0] += 1.0;
                self.learned += 1;
                Ok(())
            }
            fn infer(
                &mut self,
                _ex: &Example,
                _be: &mut dyn ComputeBackend,
            ) -> Result<crate::learning::Verdict> {
                Ok(crate::learning::Verdict::Unknown)
            }
            fn learnable(&self) -> bool {
                true
            }
            fn evaluate(&mut self, _be: &mut dyn ComputeBackend) -> Result<f32> {
                Ok(0.0)
            }
            fn learned_count(&self) -> u64 {
                self.learned
            }
            fn save(&mut self, nvm: &mut Nvm) -> Result<()> {
                nvm.write_f32s("forget/state", &self.state)?;
                nvm.write_u64("forget/learned", self.learned)
            }
            fn restore(&mut self, nvm: &mut Nvm) -> Result<()> {
                // bug: forget/learned is saved but never read back
                if let Some(xs) = nvm.read_f32s("forget/state") {
                    self.state = xs;
                }
                Ok(())
            }
            fn name(&self) -> &'static str {
                "forgetful_fixture"
            }
        }
        let mut be = NativeBackend::new();
        let make = |_: u64| {
            Box::new(ForgetfulLearner {
                state: vec![0.0; 4],
                learned: 0,
            }) as Box<dyn Learner>
        };
        let findings = analyze_learner(&make, &mut be, 7).unwrap();
        assert!(
            findings
                .iter()
                .any(|f| f.rule == RULE_PARITY && f.key == "forget/learned"),
            "{findings:?}"
        );
    }

    #[test]
    fn lint_flags_war_and_atomicity_on_a_synthetic_trace() {
        let trace = AccessTrace {
            events: vec![
                AccessEvent::Write {
                    key: "loose".into(),
                    range: (0, 8),
                    full: true,
                    in_txn: false,
                },
                AccessEvent::Begin,
                AccessEvent::Read {
                    key: "row".into(),
                    range: (0, 16),
                    committed: vec![(0, 16)],
                    in_txn: true,
                },
                AccessEvent::Write {
                    key: "row".into(),
                    range: (4, 8),
                    full: false,
                    in_txn: true,
                },
                // full overwrite after a read replays cleanly: exempt
                AccessEvent::Read {
                    key: "gen".into(),
                    range: (0, 8),
                    committed: vec![(0, 8)],
                    in_txn: true,
                },
                AccessEvent::Write {
                    key: "gen".into(),
                    range: (0, 8),
                    full: true,
                    in_txn: true,
                },
                AccessEvent::Commit,
                // the bracket cleared the read set: no WAR across actions
                AccessEvent::Begin,
                AccessEvent::Write {
                    key: "row".into(),
                    range: (0, 4),
                    full: false,
                    in_txn: true,
                },
                AccessEvent::Commit,
            ],
        };
        let findings = lint_trace(&trace);
        assert_eq!(rules(&findings), vec![RULE_ATOM, RULE_WAR], "{findings:?}");
        assert_eq!(findings[0].key, "loose");
        assert_eq!(findings[1].key, "row");
        assert_eq!(findings[1].range, Some((4, 8)));
    }

    #[test]
    fn healed_rollback_shows_in_the_trace_and_lints_clean() {
        use crate::nvm::audit::AccessEvent as E;
        let trace = fixtures::healed_rollback_trace();
        assert!(
            trace
                .events
                .iter()
                .any(|e| matches!(e, E::Flush { key, .. } if key == "fix/a")),
            "{:?}",
            trace.events
        );
        assert!(
            trace
                .events
                .iter()
                .any(|e| matches!(e, E::Heal { rolled_back: true })),
            "{:?}",
            trace.events
        );
        // the cut landed before the commit record was written
        assert!(!trace.events.iter().any(|e| matches!(e, E::Record { .. })));
        // a healed rollback is safe: the linter has nothing to flag
        assert!(lint_trace(&trace).is_empty());
    }

    #[test]
    fn shipped_learners_and_run_state_clean_on_all_presets() {
        for name in PRESETS {
            let report = analyze_preset(name).unwrap();
            assert!(report.is_clean(), "{name}: {:?}", report.entries);
        }
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn clean_report_json_matches_the_committed_golden_shape() {
        let report = analyze_preset("air_quality").unwrap();
        assert_eq!(
            report.to_json().to_string(),
            "{\"scenario\":\"air_quality\",\"findings_total\":0,\"entries\":[\
             {\"learner\":\"knn\",\"backend\":\"native\",\"findings\":[]},\
             {\"learner\":\"cluster_label\",\"backend\":\"native\",\"findings\":[]},\
             {\"learner\":\"run_state\",\"backend\":\"-\",\"findings\":[]}]}"
        );
    }
}
