//! Ablation benches for the design choices DESIGN.md calls out: the
//! planner's §4.3 search refinements (horizon L, admitted-example cap,
//! discount γ) and the select-gate expectation. Each row is a full 4 h
//! vibration run; the interesting outputs are accuracy, learned count and
//! planner decision latency.
//!
//!     cargo bench --bench ablations

use ilearn::actions::Action;
use ilearn::apps::AppKind;
use ilearn::energy::CostModel;
use ilearn::learning::KnnAnomalyLearner;
use ilearn::planner::{DynamicActionPlanner, PlanContext, PlannerConfig};
use ilearn::selection::Heuristic;
use ilearn::sim::engine::Engine;
use ilearn::sim::PlannerScheduler;
use ilearn::util::bench::{bench, black_box, time_once};

const H: u64 = 3_600_000_000;

fn run_with_planner(cfg_mod: impl Fn(&mut PlannerConfig)) -> ilearn::sim::RunResult {
    let spec = AppKind::Vibration.spec(42, 4 * H);
    let mut pc = PlannerConfig::default();
    cfg_mod(&mut pc);
    let planner = DynamicActionPlanner::new(spec.goal, pc);
    let engine = Engine::builder()
        .sim(spec.sim_config())
        .harvester(spec.build_harvester())
        .capacitor(spec.build_capacitor())
        .sensor(spec.build_sensor())
        .learner(Box::new(KnnAnomalyLearner::new()))
        .selector(Heuristic::RoundRobin.build(42))
        .scheduler(Box::new(PlannerScheduler(planner)))
        .costs(spec.cost.build())
        .build()
        .unwrap();
    engine.run().unwrap()
}

fn main() {
    println!("== ablation: planning horizon L (paper §4.3: L ~ longest path) ==");
    println!(
        "{:>3} {:>9} {:>9} {:>9} {:>12}",
        "L", "mean_acc", "learned", "inferred", "decision_p50"
    );
    for horizon in [2usize, 4, 7, 10] {
        let (r, _) = time_once("run", || run_with_planner(|c| c.horizon = horizon));
        let mut planner = DynamicActionPlanner::default();
        planner.cfg.horizon = horizon;
        let costs = CostModel::kmeans();
        let pending = vec![Action::Decide, Action::Sense];
        let ctx = PlanContext {
            learned_total: 50,
            quality: 0.5,
            window_learns: 1,
            window_infers: 1,
            window_cycle: 2,
            forecast_uj: None,
        };
        let m = bench("d", 60, || {
            black_box(planner.next_action(&pending, &ctx, &costs));
        });
        println!(
            "{:>3} {:>9.2} {:>9} {:>9} {:>10.1}us",
            horizon,
            r.mean_accuracy(3),
            r.learned,
            r.inferred,
            m.p50_ns / 1000.0
        );
    }

    println!("\n== ablation: admitted-example cap (paper uses 2 in §7.5) ==");
    for cap in [1usize, 2, 3] {
        let (r, m) = time_once("run", || run_with_planner(|c| c.max_admitted = cap));
        println!(
            "cap={cap}: mean_acc {:.2} learned {} inferred {} (run wall {})",
            r.mean_accuracy(3),
            r.learned,
            r.inferred,
            ilearn::util::bench::fmt_ns(m.mean_ns)
        );
    }

    println!("\n== ablation: discount gamma (procrastination guard) ==");
    for gamma in [1.0f64, 0.95, 0.85, 0.6] {
        let (r, _) = time_once("run", || run_with_planner(|c| c.gamma = gamma));
        println!(
            "gamma={gamma:.2}: mean_acc {:.2} learned {} inferred {} (gamma=1.0 shows the receding-horizon procrastination pathology)",
            r.mean_accuracy(3),
            r.learned,
            r.inferred,
        );
    }

    println!("\n== ablation: planner vs fixed duty cycles on identical world ==");
    for (name, sched) in [
        ("planner", ilearn::scenario::SchedulerKind::Planner),
        ("alpaca:50", ilearn::scenario::SchedulerKind::Alpaca { learn_pct: 0.5 }),
        ("alpaca:90", ilearn::scenario::SchedulerKind::Alpaca { learn_pct: 0.9 }),
    ] {
        let mut spec = AppKind::Vibration.spec(42, 4 * H);
        spec.scheduler = sched;
        let (r, _) = time_once("run", || spec.build_engine().unwrap().run().unwrap());
        println!(
            "{name:>10}: mean_acc {:.2} learned {:>5} energy {:>8.1} mJ",
            r.mean_accuracy(3),
            r.learned,
            r.energy_uj / 1000.0
        );
    }
}
