//! Federated-sync benchmark: learner merge overhead (the compute a shard
//! pays at every sync boundary on top of the radio bill), snapshot wire
//! sizes, and a synced-vs-isolated fleet cell. Tracked over time through
//! `BENCH_sync.json` (written at the repo root when run from `rust/`).
//!
//!     cargo bench --bench sync            # full comparison + JSON
//!     cargo bench --bench sync -- --smoke # CI: merge invariants + one short cell
//!
//! The full mode times the worst-case all-reduce merges — a k-NN ring
//! merge of 15 peer rings (16-shard fleet) and the k-means count-weighted
//! centroid average — and runs a small gossip fleet against its isolated
//! twin. `--smoke` asserts the cheap invariants: merge determinism,
//! snapshot wire sizes, thread-count-identical synced fleet results, and
//! that exchanges actually happen and are metered.

use ilearn::backend::native::NativeBackend;
use ilearn::backend::shapes::{FEAT_DIM, N_BUF, N_CLUSTERS};
use ilearn::learning::{
    ClusterLabelLearner, Example, KnnAnomalyLearner, Learner, ModelSnapshot,
};
use ilearn::scenario::{preset, FleetSpec, SyncSpec};
use ilearn::sim::SyncStrategy;
use ilearn::util::bench::{bench, time_once};
use ilearn::util::json::Json;
use ilearn::util::Rng;
use std::time::Instant;

const H: u64 = 3_600_000_000;

fn trained_knn(seed: u64, n: usize, t0: u64) -> KnnAnomalyLearner {
    let mut be = NativeBackend::new();
    let mut l = KnnAnomalyLearner::new();
    let mut rng = Rng::new(seed);
    for t in 0..n as u64 {
        let f: Vec<f32> = (0..FEAT_DIM).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        l.learn(&Example::new(f, t0 + t, false), &mut be).unwrap();
    }
    l
}

fn trained_kmeans(seed: u64, n: usize) -> ClusterLabelLearner {
    let mut be = NativeBackend::new();
    let mut l = ClusterLabelLearner::new(seed, 20);
    let mut rng = Rng::new(seed);
    for i in 0..n {
        let abnormal = i % 2 == 0;
        let mut f = vec![0.0f32; FEAT_DIM];
        let base = if abnormal { 8 } else { 0 };
        for v in f[base..base + 8].iter_mut() {
            *v = 2.0 + rng.normal(0.0, 0.2) as f32;
        }
        l.learn(&Example::new(f, i as u64, abnormal), &mut be).unwrap();
    }
    l
}

fn knn_peers(count: usize) -> Vec<ModelSnapshot> {
    (0..count)
        .map(|i| {
            trained_knn(100 + i as u64, N_BUF, 1_000 * i as u64)
                .snapshot()
                .expect("knn snapshots")
        })
        .collect()
}

fn kmeans_peers(count: usize) -> Vec<ModelSnapshot> {
    (0..count)
        .map(|i| {
            trained_kmeans(100 + i as u64, 40)
                .snapshot()
                .expect("kmeans snapshots")
        })
        .collect()
}

fn synced_fleet_spec(shards: u32, hours: u64, period_us: u64) -> ilearn::scenario::ScenarioSpec {
    let mut spec = preset("vibration", 42, hours * H).expect("preset");
    spec.fleet = Some(FleetSpec {
        shards,
        phase_jitter_us: 30_000_000,
        seed_stride: 1,
        overrides: vec![],
        sync: Some(SyncSpec {
            period_us,
            strategy: SyncStrategy::Gossip,
            radio: None,
        }),
        sched: None,
        stream: None,
    });
    spec
}

fn smoke() {
    let t0 = Instant::now();
    // snapshot wire sizes match the model shapes (what the radio bills)
    let knn_snap = trained_knn(1, N_BUF, 0).snapshot().unwrap();
    assert_eq!(
        knn_snap.bytes(),
        N_BUF * FEAT_DIM * 4 + N_BUF * 4 + N_BUF * 8 + 8 + 8 + 4,
        "knn snapshot wire size drifted"
    );
    let km_snap = trained_kmeans(1, 40).snapshot().unwrap();
    assert_eq!(
        km_snap.bytes(),
        N_CLUSTERS * FEAT_DIM * 4 + N_CLUSTERS * 4 + N_CLUSTERS * 2 * 4 + N_CLUSTERS * 4 + 8,
        "kmeans snapshot wire size drifted"
    );
    // merge determinism: the same inputs merge to the same model
    let peers = knn_peers(3);
    let peer_refs: Vec<&ModelSnapshot> = peers.iter().collect();
    let mut be = NativeBackend::new();
    let mut a = trained_knn(7, 40, 50_000);
    let mut b = trained_knn(7, 40, 50_000);
    assert!(a.merge(&peer_refs, &mut be, 100_000, None).unwrap());
    assert!(b.merge(&peer_refs, &mut be, 100_000, None).unwrap());
    assert_eq!(a.buffer().0, b.buffer().0, "knn merge nondeterministic");
    assert_eq!(a.threshold(), b.threshold());
    // delta snapshots: the full ring rides the first contact, then only
    // the slots learned since the last committed broadcast
    let mut d = trained_knn(2, N_BUF, 0);
    assert!(
        matches!(d.snapshot_outgoing().unwrap(), ModelSnapshot::Knn { .. }),
        "first contact must radio the full ring"
    );
    d.note_broadcast();
    let empty = d.snapshot_outgoing().unwrap();
    assert_eq!(empty.bytes(), 8 + 4, "empty delta wire size drifted");
    let f: Vec<f32> = vec![0.5; FEAT_DIM];
    d.learn(&Example::new(f, 999_999, false), &mut be).unwrap();
    let one_slot = d.snapshot_outgoing().unwrap();
    assert_eq!(
        one_slot.bytes(),
        FEAT_DIM * 4 + 8 + 8 + 4,
        "one-slot delta wire size drifted"
    );
    assert_eq!(
        one_slot.full_bytes(),
        knn_snap.bytes(),
        "delta full-snapshot fallback size drifted"
    );
    // a short synced fleet: bit-identical across thread counts, exchanges
    // happen and are metered
    let spec = synced_fleet_spec(3, 1, 20 * 60 * 1_000_000);
    let serial = spec.run_fleet(1).expect("serial synced fleet");
    let pooled = spec.run_fleet(0).expect("pooled synced fleet");
    assert_eq!(
        serial.to_json().to_string(),
        pooled.to_json().to_string(),
        "synced fleet diverged across thread counts"
    );
    let done = serial.rollup.syncs_done.total as u64;
    assert!(done > 0, "no sync exchange completed in the smoke cell");
    let tx: u64 = serial
        .shards
        .iter()
        .flat_map(|r| &r.action_tallies)
        .filter(|(n, ..)| n == "tx")
        .map(|&(_, c, ..)| c)
        .sum();
    assert_eq!(tx, done, "radio tallies disagree with sync counters");
    println!(
        "sync --smoke: merge invariants + 3-shard synced cell ok ({done} exchanges, {:.1}s)",
        t0.elapsed().as_secs_f64()
    );
}

fn full() {
    // worst-case all-reduce merge compute: 15 peers (a 16-shard fleet)
    let knn15 = knn_peers(15);
    let knn15_refs: Vec<&ModelSnapshot> = knn15.iter().collect();
    let base_knn = trained_knn(7, N_BUF, 50_000);
    let mut be = NativeBackend::new();
    let m_knn = bench("knn-ring-merge-15-peers", 1_500, || {
        let mut l = base_knn.clone();
        ilearn::util::bench::black_box(l.merge(&knn15_refs, &mut be, 100_000, None).unwrap());
    });
    let km15 = kmeans_peers(15);
    let km15_refs: Vec<&ModelSnapshot> = km15.iter().collect();
    let base_km = trained_kmeans(7, 40);
    let m_km = bench("kmeans-centroid-merge-15-peers", 1_500, || {
        let mut l = base_km.clone();
        ilearn::util::bench::black_box(l.merge(&km15_refs, &mut be, 100_000, None).unwrap());
    });
    println!("{}", m_knn.row());
    println!("{}", m_km.row());
    // merge overhead vs the learn payload it rides next to
    let m_learn = bench("knn-learn-payload", 1_500, || {
        let mut l = base_knn.clone();
        let mut rng = Rng::new(1);
        let f: Vec<f32> = (0..FEAT_DIM).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        l.learn(&Example::new(f, 123, false), &mut be).unwrap();
    });
    println!("{}", m_learn.row());

    // synced vs isolated fleet cell
    let synced_spec = synced_fleet_spec(8, 2, 30 * 60 * 1_000_000);
    let mut isolated_spec = synced_spec.clone();
    isolated_spec.fleet.as_mut().unwrap().sync = None;
    let (synced, sm) = time_once("fleet-8x2h-synced", || {
        synced_spec.run_fleet(0).expect("synced fleet")
    });
    let (isolated, im) = time_once("fleet-8x2h-isolated", || {
        isolated_spec.run_fleet(0).expect("isolated fleet")
    });
    println!("{}", sm.row());
    println!("{}", im.row());
    println!(
        "sync overhead: {:.1}% wall, {} exchanges / {} skips, accuracy {:.3} -> {:.3}",
        100.0 * (sm.mean_ns - im.mean_ns) / im.mean_ns.max(1.0),
        synced.rollup.syncs_done.total as u64,
        synced.rollup.syncs_skipped.total as u64,
        isolated.rollup.mean_accuracy.mean,
        synced.rollup.mean_accuracy.mean
    );

    let knn_snap = base_knn.snapshot().unwrap();
    let km_snap = base_km.snapshot().unwrap();
    // delta snapshot wire sizes: what `commit_sync` bills after the
    // first (full) contact
    let (delta_empty, delta_one_slot) = {
        let mut d = base_knn.clone();
        d.note_broadcast();
        let empty = d.snapshot_outgoing().unwrap().bytes();
        let f: Vec<f32> = vec![0.5; FEAT_DIM];
        d.learn(&Example::new(f, 999_999, false), &mut be).unwrap();
        (empty, d.snapshot_outgoing().unwrap().bytes())
    };
    let doc = Json::obj(vec![
        ("bench", Json::Str("sync".into())),
        ("knn_merge_15_peers_ns", Json::Num(m_knn.mean_ns)),
        ("kmeans_merge_15_peers_ns", Json::Num(m_km.mean_ns)),
        ("knn_learn_payload_ns", Json::Num(m_learn.mean_ns)),
        (
            "knn_merge_over_learn",
            Json::Num(m_knn.mean_ns / m_learn.mean_ns.max(1.0)),
        ),
        ("knn_snapshot_bytes", Json::Num(knn_snap.bytes() as f64)),
        ("kmeans_snapshot_bytes", Json::Num(km_snap.bytes() as f64)),
        ("knn_delta_empty_bytes", Json::Num(delta_empty as f64)),
        ("knn_delta_one_slot_bytes", Json::Num(delta_one_slot as f64)),
        ("fleet_shards", Json::Num(8.0)),
        ("fleet_sim_hours_per_shard", Json::Num(2.0)),
        ("fleet_synced_ms", Json::Num(sm.mean_ns / 1e6)),
        ("fleet_isolated_ms", Json::Num(im.mean_ns / 1e6)),
        ("fleet_syncs_done", Json::Num(synced.rollup.syncs_done.total)),
        (
            "fleet_syncs_skipped",
            Json::Num(synced.rollup.syncs_skipped.total),
        ),
        (
            "fleet_mean_accuracy_isolated",
            Json::Num(isolated.rollup.mean_accuracy.mean),
        ),
        (
            "fleet_mean_accuracy_synced",
            Json::Num(synced.rollup.mean_accuracy.mean),
        ),
    ]);
    let path = "../BENCH_sync.json";
    match std::fs::write(path, doc.to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}

fn main() {
    if std::env::args().any(|a| a == "--smoke") {
        smoke();
    } else {
        full();
    }
}
