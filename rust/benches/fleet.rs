//! Fleet-layer benchmark: shard-parallel fleet runs vs the serial
//! baseline, tracked over time through `BENCH_fleet.json` (written at the
//! repo root when run from `rust/`).
//!
//!     cargo bench --bench fleet            # full comparison + JSON
//!     cargo bench --bench fleet -- --smoke # CI: one short fleet cell + asserts
//!
//! The full mode runs an 8-shard vibration fleet serially and on the
//! worker pool and reports the wall-clock scaling (the fleet's shards are
//! independent engines, so the speedup should track the core count until
//! shard wall times dominate). `--smoke` runs a 4-shard cell and asserts
//! the fan-in contract: rollup totals equal the per-shard sums, and the
//! `FleetResult` is bit-identical across thread counts.

use ilearn::scenario::{preset, FleetSpec};
use ilearn::sim::FleetResult;
use ilearn::util::bench::{fmt_ns, time_once};
use ilearn::util::json::Json;
use std::time::Instant;

const H: u64 = 3_600_000_000;

fn fleet_spec(shards: u32, hours: u64) -> ilearn::scenario::ScenarioSpec {
    let mut spec = preset("vibration", 42, hours * H).expect("preset");
    spec.fleet = Some(FleetSpec {
        shards,
        phase_jitter_us: 30_000_000,
        seed_stride: 1,
        overrides: vec![],
        sync: None,
        sched: None,
        stream: None,
    });
    spec
}

fn fingerprint(f: &FleetResult) -> String {
    f.to_json().to_string()
}

fn assert_fan_in(f: &FleetResult, shards: u32) {
    assert_eq!(f.shards.len(), shards as usize);
    assert_eq!(f.rollup.shards, shards as usize);
    let learned: u64 = f.shards.iter().map(|r| r.learned).sum();
    assert_eq!(f.rollup.learned.total, learned as f64, "rollup != shard sum");
    let energy: f64 = f.shards.iter().map(|r| r.energy_uj).sum();
    assert!((f.rollup.energy_uj.total - energy).abs() < 1e-6);
    assert!(f.shards.iter().any(|r| r.sensed > 0), "dead fleet cell");
}

fn smoke() {
    let spec = fleet_spec(4, 1);
    let t0 = Instant::now();
    let serial = spec.run_fleet(1).expect("serial fleet");
    let threaded = spec.run_fleet(0).expect("threaded fleet");
    assert_fan_in(&serial, 4);
    assert_eq!(
        fingerprint(&serial),
        fingerprint(&threaded),
        "fleet diverged across thread counts"
    );
    println!(
        "fleet --smoke: 4-shard vibration cell ok ({} learned total, {:.1}s)",
        serial.rollup.learned.total as u64,
        t0.elapsed().as_secs_f64()
    );
}

fn full() {
    const SHARDS: u32 = 8;
    let spec = fleet_spec(SHARDS, 2);
    let (serial, sm) = time_once("fleet-8x2h-serial", || {
        spec.run_fleet(1).expect("serial fleet")
    });
    let (pooled, pm) = time_once("fleet-8x2h-pooled", || {
        spec.run_fleet(0).expect("pooled fleet")
    });
    assert_fan_in(&serial, SHARDS);
    assert_eq!(fingerprint(&serial), fingerprint(&pooled));
    let (serial_ns, pool_ns) = (sm.mean_ns, pm.mean_ns);
    let speedup = serial_ns / pool_ns.max(1.0);
    println!("{}", sm.row());
    println!("{}", pm.row());
    println!(
        "fleet {SHARDS} shards x 2h vibration: serial {} pooled {} speedup {speedup:.2}x",
        fmt_ns(serial_ns),
        fmt_ns(pool_ns)
    );
    let doc = Json::obj(vec![
        ("bench", Json::Str("fleet".into())),
        ("shards", Json::Num(SHARDS as f64)),
        ("sim_hours_per_shard", Json::Num(2.0)),
        ("serial_ms", Json::Num(serial_ns / 1e6)),
        ("pooled_ms", Json::Num(pool_ns / 1e6)),
        ("speedup", Json::Num(speedup)),
        (
            "workers",
            Json::Num(ilearn::util::pool::resolve_workers(0, SHARDS as usize) as f64),
        ),
        ("learned_total", Json::Num(serial.rollup.learned.total)),
    ]);
    let path = "../BENCH_fleet.json";
    match std::fs::write(path, doc.to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}

fn main() {
    let smoke_mode = std::env::args().any(|a| a == "--smoke");
    if smoke_mode {
        smoke();
    } else {
        full();
    }
}
