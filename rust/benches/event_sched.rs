//! Event-scheduler benchmark: the global event heap against the PR-5
//! round barrier. Tracked over time through `BENCH_sched.json` (written
//! at the repo root when run from `rust/`).
//!
//!     cargo bench --bench event_sched            # full comparison + JSON
//!     cargo bench --bench event_sched -- --smoke # CI: rollup equality
//!
//! The full mode times a 64-shard uniform-period fleet under both
//! coordinators (they must produce bit-identical results — the wall gap
//! is pure scheduling overhead) and then runs the barrier-inexpressible
//! case: a 64-shard fleet on a 30/60/90 min cadence mix, reporting its
//! wall time and the wake-event accounting (the heap schedules one event
//! per shard-local boundary; a barrier would drag all 64 shards to every
//! fastest-cadence boundary). `--smoke` asserts event-vs-rounds rollup
//! equality and the per-shard boundary attendance on small cells.

use ilearn::scenario::{preset, FleetSpec, ScenarioSpec, ShardOverride, SyncSpec};
use ilearn::sim::{planned_wakes, FleetSched, SyncStrategy};
use ilearn::util::bench::time_once;
use ilearn::util::json::Json;
use std::time::Instant;

const H: u64 = 3_600_000_000;
const MIN30: u64 = 1_800_000_000;

/// A synced vibration fleet; shard `i` syncs every `(1 + i % 3) × 30`
/// minutes when `heterogeneous`, else every 30 minutes.
fn fleet_spec(shards: u32, hours: u64, heterogeneous: bool, sched: FleetSched) -> ScenarioSpec {
    let mut spec = preset("vibration", 42, hours * H).expect("preset");
    let overrides = if heterogeneous {
        (0..shards)
            .filter(|i| i % 3 != 0)
            .map(|i| ShardOverride::sync_period(i, u64::from(1 + i % 3) * MIN30))
            .collect()
    } else {
        vec![]
    };
    spec.fleet = Some(FleetSpec {
        shards,
        phase_jitter_us: 30_000_000,
        seed_stride: 1,
        overrides,
        sync: Some(SyncSpec {
            period_us: MIN30,
            strategy: SyncStrategy::Gossip,
            radio: None,
        }),
        sched: Some(sched),
        stream: None,
    });
    spec
}

/// Shard `i`'s cadence under the `fleet_spec` pattern.
fn periods(shards: u32, heterogeneous: bool) -> Vec<u64> {
    (0..shards)
        .map(|i| {
            if heterogeneous {
                u64::from(1 + i % 3) * MIN30
            } else {
                MIN30
            }
        })
        .collect()
}

fn smoke() {
    let t0 = Instant::now();
    // event vs rounds: bit-identical rollups on a short uniform cell,
    // and the event side is thread-count deterministic
    let golden = fleet_spec(4, 2, false, FleetSched::Rounds)
        .run_fleet(0)
        .expect("rounds fleet");
    assert!(
        golden.rollup.syncs_done.total > 0.0,
        "barrier reference never exchanged"
    );
    let event_spec = fleet_spec(4, 2, false, FleetSched::Event);
    for threads in [1, 0] {
        let event = event_spec.run_fleet(threads).expect("event fleet");
        assert_eq!(
            event.to_json().to_string(),
            golden.to_json().to_string(),
            "event scheduler diverged from the round barrier (threads {threads})"
        );
    }
    // heterogeneous cadences: every shard attends exactly its own
    // strict-interior boundaries, nothing drags it to the others'
    let het = fleet_spec(3, 2, true, FleetSched::Event)
        .run_fleet(0)
        .expect("heterogeneous fleet");
    let attempts: Vec<u64> = het
        .shards
        .iter()
        .map(|r| r.syncs_done + r.syncs_skipped + r.syncs_solo)
        .collect();
    assert_eq!(attempts, vec![3, 1, 1], "per-shard boundary attendance");
    assert_eq!(
        attempts.iter().sum::<u64>(),
        planned_wakes(&periods(3, true), 2 * H),
        "heap wake accounting drifted"
    );
    println!(
        "event_sched --smoke: event==rounds + heterogeneous attendance ok ({:.1}s)",
        t0.elapsed().as_secs_f64()
    );
}

fn full() {
    // 64 shards, one uniform cadence: the two coordinators must agree
    // bit for bit, so the wall gap is pure scheduling overhead
    let (rounds, rm) = time_once("fleet-64x2h-rounds-barrier", || {
        fleet_spec(64, 2, false, FleetSched::Rounds)
            .run_fleet(0)
            .expect("rounds fleet")
    });
    let (event, em) = time_once("fleet-64x2h-event-heap", || {
        fleet_spec(64, 2, false, FleetSched::Event)
            .run_fleet(0)
            .expect("event fleet")
    });
    assert_eq!(
        rounds.to_json().to_string(),
        event.to_json().to_string(),
        "uniform-period coordinators disagree"
    );
    println!("{}", rm.row());
    println!("{}", em.row());

    // the barrier-inexpressible case: 30/60/90 min cadences across 64
    // shards — only the event heap runs it, and it schedules one wake
    // per shard-local boundary instead of 64 per fastest boundary
    let het_periods = periods(64, true);
    let horizon = 4 * H;
    let (het, hm) = time_once("fleet-64x4h-heterogeneous-event", || {
        fleet_spec(64, 4, true, FleetSched::Event)
            .run_fleet(0)
            .expect("heterogeneous fleet")
    });
    println!("{}", hm.row());
    let event_wakes = planned_wakes(&het_periods, horizon);
    let fastest = *het_periods.iter().min().expect("periods");
    let barrier_wakes = 64 * ((horizon - 1) / fastest);
    println!(
        "wake events: {event_wakes} (heap) vs {barrier_wakes} (barrier equivalent), \
         {:.2}x fewer; {} exchanges / {} solo",
        barrier_wakes as f64 / event_wakes as f64,
        het.rollup.syncs_done.total as u64,
        het.rollup.syncs_solo.total as u64,
    );

    let doc = Json::obj(vec![
        ("bench", Json::Str("event_sched".into())),
        ("fleet_shards", Json::Num(64.0)),
        ("uniform_sim_hours_per_shard", Json::Num(2.0)),
        ("uniform_rounds_ms", Json::Num(rm.mean_ns / 1e6)),
        ("uniform_event_ms", Json::Num(em.mean_ns / 1e6)),
        ("het_sim_hours_per_shard", Json::Num(4.0)),
        ("het_periods_min_pattern", Json::Str("30/60/90".into())),
        ("het_event_ms", Json::Num(hm.mean_ns / 1e6)),
        ("het_event_wakes", Json::Num(event_wakes as f64)),
        ("het_barrier_wakes", Json::Num(barrier_wakes as f64)),
        (
            "het_wake_ratio",
            Json::Num(barrier_wakes as f64 / event_wakes as f64),
        ),
        ("het_syncs_done", Json::Num(het.rollup.syncs_done.total)),
        ("het_syncs_solo", Json::Num(het.rollup.syncs_solo.total)),
        (
            "het_syncs_skipped",
            Json::Num(het.rollup.syncs_skipped.total),
        ),
    ]);
    let path = "../BENCH_sched.json";
    match std::fs::write(path, doc.to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}

fn main() {
    if std::env::args().any(|a| a == "--smoke") {
        smoke();
    } else {
        full();
    }
}
