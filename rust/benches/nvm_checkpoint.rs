//! NVM checkpoint bench: full-save vs dirty-slot delta-save learn cycles,
//! tracked over time through `BENCH_nvm.json` (written at the repo root
//! when run from `rust/`).
//!
//!     cargo bench --bench nvm_checkpoint            # full comparison + JSON
//!     cargo bench --bench nvm_checkpoint -- --smoke # CI: short cells + asserts
//!
//! Each cell runs the steady-state learn cycle — `Learner::learn` followed
//! by a checkpoint — on the native backend and reports wall time plus the
//! NVM byte accounting per learn. `full` checkpoints with `Learner::save`
//! (the pre-delta engine behaviour: the whole model re-serialized every
//! learn); `delta` with `Learner::save_delta` (only the overwritten ring
//! slot / winner row plus scalars). Because the engine charges energy per
//! NVM byte, `bytes_written_per_learn` is the energy-model-visible win;
//! the wall-time ratio is the sweep-throughput win. The capacity axis
//! exercises the O(1) running-counter capacity check against the
//! unlimited store (the old implementation rescanned every key per
//! write).

use ilearn::backend::native::NativeBackend;
use ilearn::backend::shapes::{FEAT_DIM, N_BUF};
use ilearn::learning::{ClusterLabelLearner, Example, KnnAnomalyLearner, Learner};
use ilearn::nvm::Nvm;
use ilearn::util::bench::fmt_ns;
use ilearn::util::json::Json;
use ilearn::util::Rng;
use std::time::Instant;

/// One measured cell.
struct Cell {
    name: String,
    mode: &'static str,
    capacity: usize,
    learns: usize,
    ns_per_learn: f64,
    bytes_written_per_learn: f64,
    bytes_read_per_learn: f64,
}

impl Cell {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("mode", Json::Str(self.mode.into())),
            ("capacity", Json::Num(self.capacity as f64)),
            ("learns", Json::Num(self.learns as f64)),
            ("ns_per_learn", Json::Num(self.ns_per_learn)),
            ("learns_per_sec", Json::Num(1e9 / self.ns_per_learn.max(1.0))),
            (
                "bytes_written_per_learn",
                Json::Num(self.bytes_written_per_learn),
            ),
            ("bytes_read_per_learn", Json::Num(self.bytes_read_per_learn)),
        ])
    }

    fn row(&self) -> String {
        format!(
            "{:<22} {:<6} cap {:>8} {:>10} {:>12}/learn {:>10.1} B written/learn {:>10.1} B read/learn",
            self.name,
            self.mode,
            if self.capacity == 0 {
                "inf".to_string()
            } else {
                self.capacity.to_string()
            },
            self.learns,
            fmt_ns(self.ns_per_learn),
            self.bytes_written_per_learn,
            self.bytes_read_per_learn,
        )
    }
}

fn example(rng: &mut Rng, t: u64) -> Example {
    Example::new(
        (0..FEAT_DIM).map(|_| rng.normal(0.0, 1.0) as f32).collect(),
        t,
        false,
    )
}

/// Steady-state learn cycle on a warmed learner: best-of-3 wall time plus
/// exact byte accounting over `learns` learn+checkpoint cycles.
fn measure_cell(
    name: &str,
    mode: &'static str,
    capacity: usize,
    learns: usize,
    mut fresh: impl FnMut() -> Box<dyn Learner>,
) -> Cell {
    let mut best_ns = f64::INFINITY;
    let mut bytes_w = 0.0;
    let mut bytes_r = 0.0;
    for _ in 0..3 {
        let mut be = NativeBackend::new();
        let mut nvm = if capacity > 0 {
            Nvm::with_capacity(capacity)
        } else {
            Nvm::new()
        };
        let mut l = fresh();
        let mut rng = Rng::new(42);
        // warm-up: fill the ring / clusters and land the first (full) save
        for t in 0..N_BUF as u64 {
            l.learn(&example(&mut rng, t), &mut be).unwrap();
        }
        match mode {
            "delta" => l.save_delta(&mut nvm).unwrap(),
            _ => l.save(&mut nvm).unwrap(),
        }
        let (w0, r0) = (nvm.bytes_written, nvm.bytes_read);
        let start = Instant::now();
        for t in 0..learns as u64 {
            l.learn(&example(&mut rng, N_BUF as u64 + t), &mut be).unwrap();
            match mode {
                "delta" => l.save_delta(&mut nvm).unwrap(),
                _ => l.save(&mut nvm).unwrap(),
            }
        }
        let ns = start.elapsed().as_nanos() as f64 / learns as f64;
        best_ns = best_ns.min(ns);
        bytes_w = (nvm.bytes_written - w0) as f64 / learns as f64;
        bytes_r = (nvm.bytes_read - r0) as f64 / learns as f64;
    }
    Cell {
        name: name.to_string(),
        mode,
        capacity,
        learns,
        ns_per_learn: best_ns,
        bytes_written_per_learn: bytes_w,
        bytes_read_per_learn: bytes_r,
    }
}

fn knn() -> Box<dyn Learner> {
    Box::new(KnnAnomalyLearner::new())
}

fn kmeans() -> Box<dyn Learner> {
    Box::new(ClusterLabelLearner::new(7, 40))
}

/// MSP430FR5994-class FRAM budget (paper Table 4 platforms).
const FRAM_CAP: usize = 256 * 1024;

fn run_cells(learns: usize) -> Vec<Cell> {
    vec![
        measure_cell("knn-learn-cycle", "full", 0, learns, knn),
        measure_cell("knn-learn-cycle", "delta", 0, learns, knn),
        measure_cell("knn-learn-cycle", "full", FRAM_CAP, learns, knn),
        measure_cell("knn-learn-cycle", "delta", FRAM_CAP, learns, knn),
        measure_cell("kmeans-learn-cycle", "full", 0, learns, kmeans),
        measure_cell("kmeans-learn-cycle", "delta", 0, learns, kmeans),
    ]
}

fn ratio(cells: &[Cell], name: &str, f: impl Fn(&Cell) -> f64) -> f64 {
    let get = |mode: &str| {
        cells
            .iter()
            .find(|c| c.name == name && c.mode == mode && c.capacity == 0)
            .map(&f)
            .unwrap_or(f64::NAN)
    };
    get("full") / get("delta")
}

fn smoke() {
    let cells = run_cells(200);
    for c in &cells {
        println!("{}", c.row());
    }
    let bytes_ratio = ratio(&cells, "knn-learn-cycle", |c| c.bytes_written_per_learn);
    println!("smoke knn bytes-written ratio full/delta: {bytes_ratio:.1}x");
    assert!(
        bytes_ratio >= 5.0,
        "delta checkpoint must write >=5x fewer bytes per learn, got {bytes_ratio:.1}x"
    );
    // capacity checks are O(1): the capped store must not be drastically
    // slower than the unlimited one (generous bound — CI boxes are noisy)
    let capped = cells
        .iter()
        .find(|c| c.mode == "delta" && c.capacity == FRAM_CAP)
        .unwrap();
    let free = cells
        .iter()
        .find(|c| c.name == "knn-learn-cycle" && c.mode == "delta" && c.capacity == 0)
        .unwrap();
    assert!(
        capped.ns_per_learn < free.ns_per_learn * 10.0 + 10_000.0,
        "capacity-checked writes look super-linear: {} vs {}",
        fmt_ns(capped.ns_per_learn),
        fmt_ns(free.ns_per_learn)
    );
    println!("smoke OK");
}

fn main() {
    if std::env::args().any(|a| a == "--smoke") {
        smoke();
        return;
    }
    let learns = 20_000;
    println!("== NVM checkpoint: full save vs dirty-slot delta save ==");
    let cells = run_cells(learns);
    for c in &cells {
        println!("{}", c.row());
    }
    let bytes_ratio = ratio(&cells, "knn-learn-cycle", |c| c.bytes_written_per_learn);
    let kmeans_ratio = ratio(&cells, "kmeans-learn-cycle", |c| c.bytes_written_per_learn);
    let speedup = ratio(&cells, "knn-learn-cycle", |c| c.ns_per_learn);
    println!("knn bytes-written ratio full/delta: {bytes_ratio:.1}x");
    println!("knn learn-cycle speedup full/delta: {speedup:.2}x");

    // same schema as python/tools/nvm_mirror.py --emit-json (which seeds
    // the tracked file with exact byte rows and null wall-time fields)
    let doc = Json::obj(vec![
        ("bench", Json::Str("nvm_checkpoint".into())),
        ("source", Json::Str("cargo bench --bench nvm_checkpoint".into())),
        ("learns", Json::Num(learns as f64)),
        ("headline_bytes_ratio", Json::Num(bytes_ratio)),
        ("headline_speedup", Json::Num(speedup)),
        ("kmeans_bytes_ratio", Json::Num(kmeans_ratio)),
        ("cells", Json::Arr(cells.iter().map(Cell::to_json).collect())),
    ]);
    // the tracked copy lives at the repo root, one level above the crate
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_nvm.json");
    std::fs::write(path, doc.to_string()).expect("write BENCH_nvm.json");
    println!("wrote {path}");
}
