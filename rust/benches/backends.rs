//! Bench: native vs PJRT dispatch cost per payload, and the batching
//! lever (§Perf): how much of the PJRT per-call overhead the batched
//! `knn_infer_batch` artifact amortizes.
//!
//!     make artifacts && cargo bench --bench backends

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!("skipping: the backends bench compares native vs PJRT — rebuild with `--features pjrt`");
}

#[cfg(feature = "pjrt")]
fn main() {
    use ilearn::backend::native::NativeBackend;
    use ilearn::backend::pjrt::PjrtBackend;
    use ilearn::backend::shapes::*;
    use ilearn::backend::ComputeBackend;
    use ilearn::util::bench::{bench, black_box};
    use ilearn::util::Rng;

    let mut rng = Rng::new(2);
    let mut ex = vec![0.0f32; N_BUF * FEAT_DIM];
    let mut mask = vec![0.0f32; N_BUF];
    for i in 0..48 {
        mask[i] = 1.0;
        for j in 0..FEAT_DIM {
            ex[i * FEAT_DIM + j] = rng.normal(0.0, 3.0) as f32;
        }
    }
    let x: Vec<f32> = (0..FEAT_DIM).map(|_| rng.normal(0.0, 3.0) as f32).collect();
    let xs: Vec<f32> = (0..BATCH * FEAT_DIM)
        .map(|_| rng.normal(0.0, 3.0) as f32)
        .collect();
    let w: Vec<f32> = (0..N_CLUSTERS * FEAT_DIM)
        .map(|_| rng.normal(0.0, 1.0) as f32)
        .collect();
    let window: Vec<f32> = (0..WINDOW * CHANNELS)
        .map(|_| rng.normal(0.0, 1.0) as f32)
        .collect();

    let mut native = NativeBackend::new();
    let pjrt = PjrtBackend::discover();
    let mut pjrt = match pjrt {
        Ok(p) => p,
        Err(e) => {
            eprintln!("skipping pjrt benches: {e}");
            return;
        }
    };

    println!("== dispatch cost: native vs pjrt (same payloads) ==");
    let rows: Vec<(String, f64, f64)> = vec![
        (
            "extract".into(),
            bench("native", 150, || {
                black_box(native.extract(&window).unwrap());
            })
            .p50_ns,
            bench("pjrt", 400, || {
                black_box(pjrt.extract(&window).unwrap());
            })
            .p50_ns,
        ),
        (
            "knn_learn".into(),
            {
                let mut scores = vec![0.0f32; N_BUF];
                bench("native", 300, || {
                    black_box(native.knn_learn(&ex, &mask, &mut scores).unwrap());
                })
                .p50_ns
            },
            {
                let mut scores = vec![0.0f32; N_BUF];
                bench("pjrt", 500, || {
                    black_box(pjrt.knn_learn(&ex, &mask, &mut scores).unwrap());
                })
                .p50_ns
            },
        ),
        (
            "knn_infer".into(),
            bench("native", 150, || {
                black_box(native.knn_infer(&ex, &mask, &x).unwrap());
            })
            .p50_ns,
            bench("pjrt", 400, || {
                black_box(pjrt.knn_infer(&ex, &mask, &x).unwrap());
            })
            .p50_ns,
        ),
        (
            "kmeans_learn".into(),
            {
                let mut w_hot = w.clone();
                let mut acts = [0.0f32; N_CLUSTERS];
                bench("native", 150, || {
                    black_box(native.kmeans_learn(&mut w_hot, &x, 0.15, &mut acts).unwrap());
                })
                .p50_ns
            },
            {
                let mut w_hot = w.clone();
                let mut acts = [0.0f32; N_CLUSTERS];
                bench("pjrt", 400, || {
                    black_box(pjrt.kmeans_learn(&mut w_hot, &x, 0.15, &mut acts).unwrap());
                })
                .p50_ns
            },
        ),
    ];
    println!(
        "{:<14} {:>14} {:>14} {:>10}",
        "payload", "native p50", "pjrt p50", "ratio"
    );
    for (name, n_ns, p_ns) in &rows {
        println!(
            "{:<14} {:>11.2} us {:>11.2} us {:>9.1}x",
            name,
            n_ns / 1000.0,
            p_ns / 1000.0,
            p_ns / n_ns.max(1.0)
        );
    }

    println!("\n== batching lever: scalar vs batched knn_infer on pjrt ==");
    let scalar = bench("pjrt knn_infer x16 (scalar loop)", 500, || {
        for b in 0..BATCH {
            black_box(
                pjrt.knn_infer(&ex, &mask, &xs[b * FEAT_DIM..(b + 1) * FEAT_DIM])
                    .unwrap(),
            );
        }
    });
    let mut scores = vec![0.0f32; BATCH];
    let batched = bench("pjrt knn_infer_batch (one dispatch)", 500, || {
        pjrt.knn_infer_batch(&ex, &mask, &xs, &mut scores).unwrap();
        black_box(scores[0]);
    });
    println!("{}", scalar.row());
    println!("{}", batched.row());
    println!(
        "batched dispatch is {:.1}x cheaper per example",
        scalar.p50_ns / batched.p50_ns.max(1.0)
    );
    println!("\ntotal pjrt dispatches this run: {}", pjrt.dispatches);
}
