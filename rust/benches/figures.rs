//! Bench: end-to-end regeneration of every paper table & figure, timed.
//! One entry per experiment in the DESIGN.md §4 index — this is the
//! "one bench per paper table" harness.
//!
//!     cargo bench --bench figures            # all
//!     cargo bench --bench figures fig9       # one

use ilearn::eval::figures;
use ilearn::util::bench::time_once;

fn main() {
    // cargo bench passes harness flags like `--bench`; only treat bare
    // words as figure filters
    let filter: Option<String> = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with('-'));
    let seed = 42;
    let mut total_s = 0.0;
    for id in figures::FIGURE_IDS {
        if let Some(f) = &filter {
            if !id.contains(f.as_str()) {
                continue;
            }
        }
        let (result, m) = time_once(id, || figures::generate(id, seed));
        total_s += m.mean_ns / 1e9;
        match result {
            Ok(fig) => {
                println!("{}", fig.render());
                println!("[bench] {}\n", m.row());
            }
            Err(e) => {
                eprintln!("[bench] {id} FAILED: {e}");
                std::process::exit(1);
            }
        }
    }
    println!("[bench] total figure regeneration time: {total_s:.1}s");
}
