//! Bench: per-action payload latency + planner/selection overhead
//! (regenerates the measured columns behind paper Figs. 16 & 17).
//!
//!     cargo bench --bench actions

use ilearn::actions::Action;
use ilearn::backend::native::NativeBackend;
use ilearn::backend::shapes::*;
use ilearn::backend::ComputeBackend;
use ilearn::energy::CostModel;
use ilearn::learning::Example;
use ilearn::planner::{DynamicActionPlanner, PlanContext};
use ilearn::selection::{Heuristic, Selector};
use ilearn::util::bench::{bench, black_box};
use ilearn::util::Rng;

fn main() {
    let mut rng = Rng::new(1);
    let mut be = NativeBackend::new();

    let mut ex = vec![0.0f32; N_BUF * FEAT_DIM];
    let mut mask = vec![0.0f32; N_BUF];
    for i in 0..48 {
        mask[i] = 1.0;
        for j in 0..FEAT_DIM {
            ex[i * FEAT_DIM + j] = rng.normal(0.0, 3.0) as f32;
        }
    }
    let x: Vec<f32> = (0..FEAT_DIM).map(|_| rng.normal(0.0, 3.0) as f32).collect();
    let w: Vec<f32> = (0..N_CLUSTERS * FEAT_DIM)
        .map(|_| rng.normal(0.0, 1.0) as f32)
        .collect();
    let window: Vec<f32> = (0..WINDOW * CHANNELS)
        .map(|_| rng.normal(0.0, 1.0) as f32)
        .collect();

    println!("== native payloads (fig16 measured column) ==");
    println!(
        "{}",
        bench("extract (64x4 window)", 150, || {
            black_box(be.extract(&window).unwrap());
        })
        .row()
    );
    let mut scores = vec![0.0f32; N_BUF];
    println!(
        "{}",
        bench("knn_learn (48/64 examples)", 300, || {
            black_box(be.knn_learn(&ex, &mask, &mut scores).unwrap());
        })
        .row()
    );
    println!(
        "{}",
        bench("knn_infer", 150, || {
            black_box(be.knn_infer(&ex, &mask, &x).unwrap());
        })
        .row()
    );
    let mut w_hot = w.clone();
    let mut acts = [0.0f32; N_CLUSTERS];
    println!(
        "{}",
        bench("kmeans_learn", 150, || {
            black_box(be.kmeans_learn(&mut w_hot, &x, 0.15, &mut acts).unwrap());
        })
        .row()
    );
    println!(
        "{}",
        bench("kmeans_infer", 150, || {
            black_box(be.kmeans_infer(&w, &x).unwrap());
        })
        .row()
    );

    println!("\n== planner decision latency (fig17 measured column) ==");
    let costs = CostModel::kmeans();
    for admitted in [1usize, 2, 3] {
        let mut planner = DynamicActionPlanner::default();
        planner.cfg.max_admitted = admitted;
        let pending: Vec<Action> = (0..admitted.min(2)).map(|_| Action::Decide).collect();
        let ctx = PlanContext {
            learned_total: 50,
            quality: 0.6,
            window_learns: 1,
            window_infers: 2,
            window_cycle: 3,
            forecast_uj: None,
        };
        println!(
            "{}",
            bench(&format!("planner.next_action (admitted={admitted})"), 150, || {
                black_box(planner.next_action(&pending, &ctx, &costs));
            })
            .row()
        );
    }

    println!("\n== selection heuristics (fig17) ==");
    for h in Heuristic::ALL {
        let mut sel = h.build(7);
        let mut i = 0u64;
        println!(
            "{}",
            bench(&format!("select/{}", h.name()), 150, || {
                i += 1;
                let mut f = x.clone();
                f[0] += (i % 17) as f32 * 0.3;
                let e = Example::new(f, i, false);
                black_box(sel.select(&e, &mut be).unwrap());
            })
            .row()
        );
    }

    println!("\n== paper cost-model anchors ==");
    for m in [CostModel::knn(), CostModel::kmeans()] {
        for a in [Action::Sense, Action::Extract, Action::Learn, Action::Infer] {
            let c = m.cost(a);
            println!(
                "{:<8} {:<8} {:>10.1} uJ {:>10.2} ms (splits {})",
                m.name,
                a.name(),
                c.energy_uj,
                c.time_us as f64 / 1000.0,
                c.splits
            );
        }
    }
}
