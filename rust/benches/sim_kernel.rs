//! Charge-kernel benchmark: stepped reference oracle vs the event-driven
//! analytic kernel, on 24 h solar worlds, tracked over time through
//! `BENCH_sim.json` (written at the repo root when run from `rust/`).
//!
//!     cargo bench --bench sim_kernel            # full comparison + JSON
//!     cargo bench --bench sim_kernel -- --smoke # CI: one short cell
//!
//! Cells:
//! * `kernel-24h-solar`         — the charge kernel in isolation (wake
//!   bursts emulated as a full discharge), default 45 mW panel.
//! * `kernel-24h-solar-starved` — the same with a 0.5 mW panel: the
//!   long-horizon sweep regime where the device sleeps hours per wake and
//!   the stepped loop crawls darkness and dawn at 60 s resolution. This
//!   is the headline cell (the stepped kernel burns >10x the iterations
//!   for identical wake counts).
//! * `cell-24h-solar` / `cell-24h-solar-longhaul` — full engine runs of
//!   the corresponding scenarios, for context: an engine cell's wall
//!   clock also contains wake-burst execution (planner + learner), which
//!   is kernel-independent, so these ratios understate the kernel win.

use ilearn::apps::AppKind;
use ilearn::scenario::HarvesterSpec;
use ilearn::sim::world::World;
use ilearn::sim::{ChargeKernel, RunResult};
use ilearn::util::bench::{fmt_ns, time_once};
use ilearn::util::json::Json;

const H: u64 = 3_600_000_000;

/// One measured cell.
struct Cell {
    name: &'static str,
    kernel: ChargeKernel,
    wall_ns: f64,
    sim_hours: f64,
    cycles: u64,
}

impl Cell {
    fn us_per_sim_hour(&self) -> f64 {
        self.wall_ns / 1_000.0 / self.sim_hours
    }

    fn cells_per_sec(&self) -> f64 {
        1e9 / self.wall_ns
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.into())),
            ("kernel", Json::Str(self.kernel.name().into())),
            ("wall_ms", Json::Num(self.wall_ns / 1e6)),
            ("us_per_sim_hour", Json::Num(self.us_per_sim_hour())),
            ("cells_per_sec", Json::Num(self.cells_per_sec())),
            ("sim_hours", Json::Num(self.sim_hours)),
            ("cycles", Json::Num(self.cycles as f64)),
        ])
    }
}

/// Best-of-3 wall time for `f`, which returns the run's cycle count.
fn measure(
    name: &'static str,
    kernel: ChargeKernel,
    sim_hours: f64,
    mut f: impl FnMut() -> u64,
) -> Cell {
    let mut best = f64::INFINITY;
    let mut cycles = 0;
    for _ in 0..3 {
        let (c, m) = time_once(name, &mut f);
        cycles = c;
        best = best.min(m.mean_ns);
    }
    Cell {
        name,
        kernel,
        wall_ns: best,
        sim_hours,
        cycles,
    }
}

/// Kernel-in-isolation: charge the air-quality world for `hours` with the
/// panel scaled to `peak_w`, emulating each wake burst as a full
/// discharge to `v_off` + 1 s awake.
fn kernel_only(kernel: ChargeKernel, hours: u64, peak_w: f64) -> u64 {
    let mut spec = AppKind::AirQuality.spec(42, hours * H);
    if let HarvesterSpec::Solar { peak_w: p, .. } = &mut spec.harvester {
        *p = peak_w;
    }
    let mut world = World::new(
        spec.build_harvester(),
        spec.build_capacitor(),
        spec.build_sensor(),
    );
    let horizon = hours * H;
    let mut wakes = 0u64;
    while world.now_us() < horizon {
        if world.charge_until(horizon, kernel, spec.charge_step_us) {
            wakes += 1;
            let drain = world.cap.usable_uj();
            world.cap.deduct_uj(drain);
            world.advance_us(1_000_000);
        }
    }
    wakes
}

/// Full engine run of the air_quality preset.
fn engine_cell(kernel: ChargeKernel, hours: u64) -> RunResult {
    let mut spec = AppKind::AirQuality.spec(42, hours * H);
    spec.charge_kernel = kernel;
    spec.build_engine().unwrap().run().unwrap()
}

/// Starved panel for the long-horizon regime: 0.5 mW peak charges the
/// 0.2 F supercap over hours, so a 24 h cell is mostly sleep (the stepped
/// oracle burns ~60x the event kernel's iterations crawling it).
const STARVED_PEAK_W: f64 = 0.0005;

/// The long-horizon sweep regime as a full engine cell: starved panel and
/// sparse, cheap checkpoints (the sweep's summary cadence).
fn longhaul_cell(kernel: ChargeKernel, hours: u64) -> RunResult {
    let mut spec = AppKind::AirQuality.spec(42, hours * H);
    spec.charge_kernel = kernel;
    if let HarvesterSpec::Solar { peak_w, .. } = &mut spec.harvester {
        *peak_w = STARVED_PEAK_W;
    }
    spec.eval_period_us = 6 * H;
    spec.probe_count = 2;
    spec.probe_lookback_us = 1_800_000_000;
    spec.build_engine().unwrap().run().unwrap()
}

fn smoke() {
    // CI smoke: one short kernel-equivalence cell
    let hours = 1;
    let mut ev = AppKind::Vibration.spec(7, hours * H);
    ev.charge_kernel = ChargeKernel::Event;
    let mut st = AppKind::Vibration.spec(7, hours * H);
    st.charge_kernel = ChargeKernel::Stepped;
    let ev = ev.build_engine().unwrap().run().unwrap();
    let st = st.build_engine().unwrap().run().unwrap();
    assert!(st.cycles > 0, "dead smoke world");
    let delta = ev.cycles.abs_diff(st.cycles) as f64;
    // piezo worlds: the stepped oracle loses the front of gestures that
    // start mid-step, so a few percent of extra event-kernel wakes is the
    // oracle's own modelling gap (see tests/kernel_equivalence.rs)
    assert!(
        delta <= (0.20 * st.cycles as f64).max(5.0),
        "smoke equivalence failed: event {} vs stepped {} cycles",
        ev.cycles,
        st.cycles
    );
    println!(
        "smoke OK: vibration 1h — event {} vs stepped {} cycles",
        ev.cycles, st.cycles
    );
    // also exercise the measuring path (short cells; no JSON written so
    // the tracked 24 h numbers are never clobbered by a smoke run)
    let stepped = measure("smoke-kernel-2h", ChargeKernel::Stepped, 2.0, || {
        kernel_only(ChargeKernel::Stepped, 2, STARVED_PEAK_W)
    });
    let event = measure("smoke-kernel-2h", ChargeKernel::Event, 2.0, || {
        kernel_only(ChargeKernel::Event, 2, STARVED_PEAK_W)
    });
    println!(
        "smoke kernel cell: stepped {} vs event {} ({:.2}x)",
        fmt_ns(stepped.wall_ns),
        fmt_ns(event.wall_ns),
        stepped.wall_ns / event.wall_ns
    );
}

fn main() {
    if std::env::args().any(|a| a == "--smoke") {
        smoke();
        return;
    }

    let hours = 24u64;
    println!("== charge kernel: stepped oracle vs event kernel (24 h solar) ==");
    let mut cells = Vec::new();
    for kernel in [ChargeKernel::Stepped, ChargeKernel::Event] {
        cells.push(measure("kernel-24h-solar", kernel, hours as f64, || {
            kernel_only(kernel, hours, 0.045)
        }));
        cells.push(measure("kernel-24h-solar-starved", kernel, hours as f64, || {
            kernel_only(kernel, hours, STARVED_PEAK_W)
        }));
        cells.push(measure("cell-24h-solar", kernel, hours as f64, || {
            engine_cell(kernel, hours).cycles
        }));
        cells.push(measure("cell-24h-solar-longhaul", kernel, hours as f64, || {
            longhaul_cell(kernel, hours).cycles
        }));
    }
    for c in &cells {
        println!(
            "{:<26} {:<8} wall {:>12}  {:>10.1} us/sim-h  {:>8.2} cells/s  {} wakes",
            c.name,
            c.kernel.name(),
            fmt_ns(c.wall_ns),
            c.us_per_sim_hour(),
            c.cells_per_sec(),
            c.cycles
        );
    }

    let speedup = |name: &str| -> f64 {
        let wall = |k: ChargeKernel| {
            cells
                .iter()
                .find(|c| c.name == name && c.kernel == k)
                .map(|c| c.wall_ns)
                .unwrap_or(f64::NAN)
        };
        wall(ChargeKernel::Stepped) / wall(ChargeKernel::Event)
    };
    let speedups: Vec<(&str, f64)> = vec![
        ("kernel-24h-solar", speedup("kernel-24h-solar")),
        ("kernel-24h-solar-starved", speedup("kernel-24h-solar-starved")),
        ("cell-24h-solar", speedup("cell-24h-solar")),
        ("cell-24h-solar-longhaul", speedup("cell-24h-solar-longhaul")),
    ];
    for (name, s) in &speedups {
        println!("speedup {name}: {s:.2}x");
    }

    let doc = Json::obj(vec![
        ("bench", Json::Str("sim_kernel".into())),
        ("sim_hours", Json::Num(hours as f64)),
        // the long-horizon charge-bound cell is the kernel's headline
        ("headline_speedup", Json::Num(speedup("kernel-24h-solar-starved"))),
        ("cells", Json::Arr(cells.iter().map(Cell::to_json).collect())),
        (
            "speedups",
            Json::obj(
                speedups
                    .iter()
                    .map(|&(name, s)| (name, Json::Num(s)))
                    .collect(),
            ),
        ),
    ]);
    // the tracked copy lives at the repo root, one level above the crate
    // (CARGO_MANIFEST_DIR keeps this correct for any invocation CWD)
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_sim.json");
    std::fs::write(path, doc.to_string()).expect("write BENCH_sim.json");
    println!("wrote {path}");
}
