//! Population-scale fleet benchmark: the streaming (fold-and-drop)
//! fan-in at shard counts where retaining per-shard results is not an
//! option, tracked through `BENCH_megafleet.json` (written at the repo
//! root when run from `rust/`).
//!
//!     cargo bench --bench fleet_scale            # full: 10^5-shard run + JSON
//!     cargo bench --bench fleet_scale -- --smoke # CI: parity + memory ceiling
//!
//! `--smoke` asserts the streaming contract cheaply: the streamed rollup
//! is bit-identical to the retained per-shard path on a small fleet
//! (threads 1 and all), then a 10^5-shard short-horizon fleet completes
//! with peak RSS under a fixed ceiling — the point of fold-and-drop.
//! Full mode runs the same population at a longer horizon and records
//! shards/sec, peak RSS and pool telemetry.

use ilearn::scenario::{preset, FleetSpec, ScenarioSpec};
use ilearn::util::json::Json;
use std::time::Instant;

const H: u64 = 3_600_000_000;
const MIN: u64 = 60_000_000;

fn fleet_spec(shards: u32, horizon_us: u64, jitter_us: u64) -> ScenarioSpec {
    let mut spec = preset("vibration", 42, horizon_us).expect("preset");
    spec.fleet = Some(FleetSpec {
        shards,
        phase_jitter_us: jitter_us,
        seed_stride: 1,
        overrides: vec![],
        sync: None,
        sched: None,
        stream: Some(true),
    });
    spec
}

/// Peak resident set (VmHWM) in bytes from `/proc/self/status`; `None`
/// off Linux.
fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: u64 = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb * 1024)
}

fn smoke() {
    let t0 = Instant::now();
    // contract: the streamed rollup equals the retained path bit for bit
    let spec = fleet_spec(6, H, 30_000_000);
    let retained = spec.run_fleet(1).expect("retained fleet");
    for threads in [1, 0] {
        let streamed = spec.run_fleet_streaming(threads).expect("streamed fleet");
        assert_eq!(
            streamed.rollup.to_json().to_string(),
            retained.rollup.to_json().to_string(),
            "streamed rollup diverged from the retained path (threads {threads})"
        );
    }
    // scale: 10^5 short-horizon shards, folded in bounded memory
    const SHARDS: u32 = 100_000;
    const CEILING_BYTES: u64 = 800 * 1024 * 1024;
    let big = fleet_spec(SHARDS, 2 * MIN, 1_000_000);
    let r = big.run_fleet_streaming(0).expect("mega fleet");
    assert_eq!(r.rollup.shards, SHARDS as usize);
    assert_eq!(r.sketches.energy_uj.count(), u64::from(SHARDS));
    // every lane after its first shard recycles the slab + backend
    assert!(r.slab_reuses >= u64::from(SHARDS) - r.workers as u64);
    assert!(r.backend_reuses >= u64::from(SHARDS) - r.workers as u64);
    if let Some(rss) = peak_rss_bytes() {
        assert!(
            rss < CEILING_BYTES,
            "peak RSS {} MiB breached the {} MiB streaming ceiling",
            rss >> 20,
            CEILING_BYTES >> 20
        );
    }
    println!(
        "fleet_scale --smoke: rollup parity + {SHARDS} shards streamed ok ({:.1}s)",
        t0.elapsed().as_secs_f64()
    );
}

fn full() {
    const SHARDS: u32 = 100_000;
    const SIM_MIN: u64 = 20;
    let spec = fleet_spec(SHARDS, SIM_MIN * MIN, 1_000_000);
    let t0 = Instant::now();
    let r = spec.run_fleet_streaming(0).expect("mega fleet");
    let secs = t0.elapsed().as_secs_f64();
    let rate = f64::from(SHARDS) / secs.max(1e-9);
    let rss_mib = peak_rss_bytes().map(|b| b as f64 / (1024.0 * 1024.0));
    println!(
        "megafleet: {SHARDS} shards x {SIM_MIN} sim-min on {} worker(s) in {secs:.1}s \
         ({rate:.0} shards/s, peak RSS {})",
        r.workers,
        rss_mib.map_or("n/a".into(), |m| format!("{m:.0} MiB")),
    );
    println!(
        "  pooled: {} slab reuse(s), {} backend reuse(s); mean final accuracy {:.3}",
        r.slab_reuses, r.backend_reuses, r.rollup.final_accuracy.mean
    );
    let doc = Json::obj(vec![
        ("bench", Json::Str("megafleet".into())),
        ("shards", Json::Num(f64::from(SHARDS))),
        ("sim_minutes_per_shard", Json::Num(SIM_MIN as f64)),
        ("wall_s", Json::Num(secs)),
        ("shards_per_sec", Json::Num(rate)),
        ("workers", Json::Num(r.workers as f64)),
        ("peak_rss_mib", rss_mib.map_or(Json::Null, Json::Num)),
        ("slab_reuses", Json::Num(r.slab_reuses as f64)),
        ("backend_reuses", Json::Num(r.backend_reuses as f64)),
        ("learned_total", Json::Num(r.rollup.learned.total)),
        ("final_accuracy_mean", Json::Num(r.rollup.final_accuracy.mean)),
        ("energy_uj_p99", Json::Num(r.sketches.energy_uj.quantile(0.99))),
    ]);
    let path = "../BENCH_megafleet.json";
    match std::fs::write(path, doc.to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}

fn main() {
    let smoke_mode = std::env::args().any(|a| a == "--smoke");
    if smoke_mode {
        smoke();
    } else {
        full();
    }
}
