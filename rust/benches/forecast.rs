//! Forecast-aware planning benchmark: checkpoint elision and sync energy
//! reserves against the default policy. Tracked over time through
//! `BENCH_forecast.json` (written at the repo root when run from `rust/`).
//!
//!     cargo bench --bench forecast            # full comparison + JSON
//!     cargo bench --bench forecast -- --smoke # CI: elision + accuracy gates
//!
//! Three claims are pinned, mirroring `python/tools/forecast_mirror.py`
//! (same EWMA cadence, lookahead and per-trace error ceilings — keep the
//! two in sync):
//!
//! 1. The EWMA forecaster tracks all three recorded preset traces within
//!    the mirror's relative-error bounds.
//! 2. On a starved 24 h solar world, forecast mode elides enough probe-grid
//!    and post-learn checkpoints to cut checkpoint NVM traffic by >= 30%,
//!    while staying within kernel-equivalence accuracy tolerance of the
//!    default policy (elision never touches what the run computes, only
//!    what it redundantly persists; the remaining drift is the
//!    harvest-sized planning budget).
//! 3. In a synced starved-solar fleet with an expensive radio, the sync
//!    reserve defers at least one pre-rendezvous learn per shard-day so
//!    `prepare_sync` stops burning a learn it then skips.

use ilearn::energy::harvester::{piecewise_mean_w, Ewma, Forecast, Trace};
use ilearn::energy::Harvester;
use ilearn::scenario::{
    preset, FleetSpec, PolicySpec, RadioSpec, ScenarioSpec, SyncSpec,
};
use ilearn::sim::{RunResult, SyncStrategy};
use ilearn::util::bench::time_once;
use ilearn::util::json::Json;
use std::time::Instant;

const H: u64 = 3_600_000_000;
const MIN30: u64 = 1_800_000_000;

/// Mirror cadence: one observation every 30 s, scored against the exact
/// piecewise mean over the next 10 min.
const STEP_US: u64 = 30_000_000;
const LOOKAHEAD_US: u64 = 600_000_000;

/// Per-trace (name, relative-error ceiling) — forecast_mirror.py's rows,
/// with slack above its measured 0.6562 / 0.1415 / 0.0720.
const TRACES: [(&str, f64); 3] = [
    ("kinetic_walk", 0.75),
    ("rf_office", 0.20),
    ("solar_day", 0.12),
];

/// |a - b| within `rel` of the larger, or within `abs` absolutely (the
/// kernel-equivalence shape from `tests/kernel_equivalence.rs`).
fn close(a: f64, b: f64, rel: f64, abs: f64) -> bool {
    (a - b).abs() <= (rel * a.abs().max(b.abs())).max(abs)
}

/// Replay a recorded trace through the EWMA at the mirror cadence; returns
/// (scored windows, mean relative error vs the exact piecewise future).
fn ewma_replay(trace: &Trace) -> (usize, f64) {
    let span = trace.points.last().expect("non-empty trace").0;
    let mut ewma = Ewma::new(Forecast::EWMA_TAU_US);
    let (mut windows, mut abs_err, mut base) = (0usize, 0.0, 0.0);
    let mut t = trace.points[0].0;
    while t + LOOKAHEAD_US <= span {
        ewma.observe(t, trace.power_w(t));
        let future = piecewise_mean_w(trace, t, t + LOOKAHEAD_US);
        abs_err += (ewma.mean_power_w() - future).abs();
        base += future;
        windows += 1;
        t += STEP_US;
    }
    assert!(base > 0.0, "trace integrates to zero power");
    (windows, abs_err / base)
}

/// The starved 24 h solar world: the air-quality preset (solar k-NN) with
/// its 0.2 F reservoir cut to 10 mF — the full usable window (~19 mJ)
/// covers barely one learn path, so every wake is checkpoint-adjacent —
/// and a 5-minute probe grid so run-state saves dominate NVM traffic.
fn starved_solar(horizon_us: u64, forecast: bool) -> ScenarioSpec {
    let mut spec = preset("air_quality", 42, horizon_us).expect("preset");
    spec.name = "starved_solar".into();
    spec.capacitor.c_f = 0.010;
    spec.eval_period_us = 300_000_000;
    if forecast {
        spec.policy = Some(PolicySpec { forecast: true });
    }
    spec
}

/// A 3-shard synced starved-solar fleet under an expensive radio (28 mJ
/// per gossip exchange against a ~96 mJ usable window): around dusk the
/// refill forecast to the next boundary goes to zero, so the reserve must
/// bind while the free budget still covers a learn.
fn starved_fleet(forecast: bool) -> ScenarioSpec {
    let mut spec = starved_solar(24 * H, forecast);
    spec.capacitor.c_f = 0.050;
    spec.fleet = Some(FleetSpec {
        shards: 3,
        phase_jitter_us: 30_000_000,
        seed_stride: 1,
        overrides: vec![],
        sync: Some(SyncSpec {
            period_us: MIN30,
            strategy: SyncStrategy::Gossip,
            radio: Some(RadioSpec {
                tx_uj: 20_000.0,
                tx_us: 85_000,
                rx_uj: 8_000.0,
                rx_us: 85_000,
            }),
        }),
        sched: None,
        stream: None,
    });
    spec
}

fn run(spec: &ScenarioSpec) -> RunResult {
    spec.build_engine().expect("engine").run().expect("run")
}

/// Gate the starved-solar pair: elision fires, the final save persists,
/// >= 30% of checkpoint NVM bytes disappear, and accuracy stays within
/// kernel-equivalence tolerance. Returns (default, forecast).
fn assert_starved_pair(horizon_us: u64) -> (RunResult, RunResult) {
    let default = run(&starved_solar(horizon_us, false));
    let forecast = run(&starved_solar(horizon_us, true));
    assert_eq!(
        default.checkpoints_taken + default.checkpoints_elided,
        0,
        "default policy must not report forecast counters"
    );
    assert!(default.ckpt_nvm_bytes > 0, "default run never checkpointed");
    assert!(
        forecast.checkpoints_elided > 0,
        "forecast mode never elided a checkpoint"
    );
    assert!(
        forecast.checkpoints_taken >= 1,
        "the final horizon save must always persist"
    );
    assert!(
        forecast.ckpt_nvm_bytes as f64 <= 0.7 * default.ckpt_nvm_bytes as f64,
        "elision saved too little NVM traffic: {} vs {} bytes",
        forecast.ckpt_nvm_bytes,
        default.ckpt_nvm_bytes
    );
    assert!(
        close(forecast.mean_accuracy(3), default.mean_accuracy(3), 0.15, 0.05)
            && close(forecast.final_accuracy(), default.final_accuracy(), 0.15, 0.05),
        "forecast mode drifted out of accuracy tolerance: mean {:.3} vs {:.3}, \
         final {:.3} vs {:.3}",
        forecast.mean_accuracy(3),
        default.mean_accuracy(3),
        forecast.final_accuracy(),
        default.final_accuracy()
    );
    (default, forecast)
}

fn smoke() {
    let t0 = Instant::now();
    // 1. the EWMA tracks every recorded preset trace within the mirror's
    //    ceilings (>= 1.0 would mean no better than predicting zero)
    for (name, bound) in TRACES {
        let trace =
            Trace::from_csv(&format!("../examples/traces/{name}.csv")).expect("trace");
        let (_, rel) = ewma_replay(&trace);
        assert!(rel < bound, "{name}: EWMA relative error {rel} >= {bound}");
    }
    // 2. starved solar: elision + byte reduction + accuracy tolerance
    let (_, forecast) = assert_starved_pair(24 * H);
    let doc = forecast.to_json().to_string();
    assert!(doc.contains("\"checkpoints_elided\""), "{doc}");
    // 3. sync reserves: at least one deferred pre-rendezvous learn per
    //    synced shard-day, and the held-back price keeps shards attending
    let fleet = starved_fleet(true).run_fleet(0).expect("fleet");
    let deferred: u64 = fleet.shards.iter().map(|r| r.learns_deferred).sum();
    let shard_days = fleet.shards.len() as u64; // 24 h horizon = 1 day each
    assert!(
        deferred >= shard_days,
        "sync reserve never bound: {deferred} deferrals over {shard_days} shard-days"
    );
    assert!(
        fleet.rollup.syncs_done.total > 0.0,
        "reserved shards never exchanged"
    );
    println!(
        "forecast --smoke: EWMA bounds + elision >=30% + reserve deferrals ok ({:.1}s)",
        t0.elapsed().as_secs_f64()
    );
}

fn full() {
    let mut kvs = vec![
        ("bench", Json::Str("forecast".into())),
        ("source", Json::Str("cargo bench --bench forecast".into())),
        ("ewma_tau_us", Json::Num(Forecast::EWMA_TAU_US as f64)),
        ("ewma_sample_step_us", Json::Num(STEP_US as f64)),
        ("ewma_lookahead_us", Json::Num(LOOKAHEAD_US as f64)),
    ];
    for (name, bound) in TRACES {
        let trace =
            Trace::from_csv(&format!("../examples/traces/{name}.csv")).expect("trace");
        let (windows, rel) = ewma_replay(&trace);
        println!("{name}: {windows} windows, mean relative error {rel:.4} (< {bound})");
        // Json::obj takes &str keys, so the per-trace names are leaked
        // once per bench process — three short strings
        let key = |s: &str| -> &'static str {
            Box::leak(format!("{name}_{s}").into_boxed_str())
        };
        kvs.push((key("windows"), Json::Num(windows as f64)));
        kvs.push((key("mean_rel_err"), Json::Num((rel * 1e4).round() / 1e4)));
        kvs.push((key("rel_err_bound"), Json::Num(bound)));
    }

    let (default, dm) = time_once("starved-solar-24h-default", || {
        run(&starved_solar(24 * H, false))
    });
    let (forecast, fm) = time_once("starved-solar-24h-forecast", || {
        run(&starved_solar(24 * H, true))
    });
    println!("{}", dm.row());
    println!("{}", fm.row());
    let saved_pct =
        100.0 * (1.0 - forecast.ckpt_nvm_bytes as f64 / default.ckpt_nvm_bytes as f64);
    let acc_delta = forecast.mean_accuracy(3) - default.mean_accuracy(3);
    println!(
        "checkpoint NVM: {} -> {} bytes ({saved_pct:.1}% saved), {} taken / {} elided, \
         accuracy delta {acc_delta:+.4}",
        default.ckpt_nvm_bytes,
        forecast.ckpt_nvm_bytes,
        forecast.checkpoints_taken,
        forecast.checkpoints_elided,
    );

    let fleet = starved_fleet(true).run_fleet(0).expect("fleet");
    let deferred: u64 = fleet.shards.iter().map(|r| r.learns_deferred).sum();
    let per_shard_day = deferred as f64 / fleet.shards.len() as f64;
    println!(
        "fleet reserves: {deferred} learns deferred across {} shards \
         ({per_shard_day:.2} per shard-day), {} exchanges",
        fleet.shards.len(),
        fleet.rollup.syncs_done.total as u64,
    );

    kvs.extend([
        (
            "starved_solar_default_ckpt_bytes",
            Json::Num(default.ckpt_nvm_bytes as f64),
        ),
        (
            "starved_solar_forecast_ckpt_bytes",
            Json::Num(forecast.ckpt_nvm_bytes as f64),
        ),
        (
            "starved_solar_ckpt_bytes_saved_pct",
            Json::Num((saved_pct * 10.0).round() / 10.0),
        ),
        (
            "starved_solar_checkpoints_taken",
            Json::Num(forecast.checkpoints_taken as f64),
        ),
        (
            "starved_solar_checkpoints_elided",
            Json::Num(forecast.checkpoints_elided as f64),
        ),
        (
            "starved_solar_accuracy_delta",
            Json::Num((acc_delta * 1e4).round() / 1e4),
        ),
        (
            "fleet_learns_deferred_per_shard_day",
            Json::Num((per_shard_day * 100.0).round() / 100.0),
        ),
        ("default_ms", Json::Num(dm.mean_ns / 1e6)),
        ("forecast_ms", Json::Num(fm.mean_ns / 1e6)),
    ]);
    let doc = Json::obj(kvs);
    let path = "../BENCH_forecast.json";
    match std::fs::write(path, doc.to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}

fn main() {
    if std::env::args().any(|a| a == "--smoke") {
        smoke();
    } else {
        full();
    }
}
